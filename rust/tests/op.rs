//! `Operator` facade properties: every backend (`Serial` / `Scoped` /
//! `Pool`) must produce **bit-identical** results on every generator
//! family, for threads ∈ {1, 2, 4} and MPK powers p ∈ 1..4 — and all of
//! them must match the plain `spmv_ref` / `powers_ref` references in
//! logical (pre-permutation) order, proving the facade's internal
//! permutation plumbing is transparent.

mod common;

use common::{families, BACKENDS, THREADS};
use race::gen;
use race::op::{self, Backend, OpConfig, Operator};
use race::sparse::Csr;

/// One operator per backend, identically configured otherwise.
fn ops(a: &Csr, threads: usize) -> Vec<(Backend, Operator)> {
    BACKENDS
        .iter()
        .map(|&bk| {
            let cfg = OpConfig::new().threads(threads).backend(bk).cache_bytes(8 << 10);
            (bk, Operator::build(a, cfg).unwrap())
        })
        .collect()
}

#[test]
fn symmspmv_bit_identical_across_backends_and_matches_reference() {
    for (name, a) in families() {
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 13 + 5) % 23) as f64 * 0.2 - 2.0).collect();
        // logical-order reference on the ORIGINAL matrix: no permutation
        // plumbing on the caller side at all
        let want = a.spmv_ref(&x);
        for threads in THREADS {
            let mut results: Vec<(Backend, Vec<f64>)> = Vec::new();
            for (bk, op) in ops(&a, threads) {
                assert_eq!(op.n(), n);
                let mut b = vec![0.0; n];
                op.symmspmv(&x, &mut b).unwrap();
                for i in 0..n {
                    assert!(
                        (want[i] - b[i]).abs() <= 1e-9 * (1.0 + want[i].abs()),
                        "{name}/t{threads}/{bk:?}: row {i}: {} vs {}",
                        want[i],
                        b[i]
                    );
                }
                results.push((bk, b));
            }
            let (bk0, b0) = &results[0];
            for (bk, b) in &results[1..] {
                assert_eq!(b0, b, "{name}/t{threads}: {bk0:?} vs {bk:?} not bit-identical");
            }
        }
    }
}

#[test]
fn symmspmv_multi_matches_singles_bitwise() {
    let m = 4usize;
    for (name, a) in families() {
        let n = a.nrows();
        let xs: Vec<Vec<f64>> = (0..m)
            .map(|j| (0..n).map(|i| ((i * (j + 3) + 2 * j) % 17) as f64 * 0.3 - 1.4).collect())
            .collect();
        for (bk, op) in ops(&a, 4) {
            let mut bs: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
            op.symmspmv_multi(&xs, &mut bs).unwrap();
            for j in 0..m {
                let mut b = vec![0.0; n];
                op.symmspmv(&xs[j], &mut b).unwrap();
                assert_eq!(b, bs[j], "{name}/{bk:?}: rhs {j}");
            }
        }
    }
}

#[test]
fn powers_bit_identical_across_backends_and_match_reference() {
    for (name, a) in families() {
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.15 - 0.9).collect();
        let want = race::mpk::powers_ref(&a, &x, 4);
        for threads in THREADS {
            let backends = ops(&a, threads);
            for p in 1..=4usize {
                let mut results: Vec<(Backend, Vec<Vec<f64>>)> = Vec::new();
                for (bk, op) in &backends {
                    let ys = op.powers(&x, p).unwrap();
                    assert_eq!(ys.len(), p);
                    for k in 0..p {
                        let err = op::rel_err(&want[k], &ys[k]);
                        assert!(
                            err <= 1e-9,
                            "{name}/t{threads}/p{p}/{bk:?}: power {} err {err:.2e}",
                            k + 1
                        );
                    }
                    results.push((*bk, ys));
                }
                let (bk0, y0) = &results[0];
                for (bk, ys) in &results[1..] {
                    assert_eq!(
                        y0, ys,
                        "{name}/t{threads}/p{p}: {bk0:?} vs {bk:?} not bit-identical"
                    );
                }
            }
        }
    }
}

#[test]
fn powers_multi_matches_singles_bitwise() {
    let a = gen::stencil2d_9pt(14, 12);
    let n = a.nrows();
    let m = 5usize;
    let xs: Vec<Vec<f64>> = (0..m)
        .map(|j| (0..n).map(|i| ((i * (j + 2) + 3 * j) % 19) as f64 * 0.25 - 2.0).collect())
        .collect();
    for threads in [1usize, 4] {
        for (bk, op) in ops(&a, threads) {
            for p in 1..=3usize {
                let ys = op.powers_multi(&xs, p).unwrap();
                assert_eq!(ys.len(), m);
                for j in 0..m {
                    let single = op.powers(&xs[j], p).unwrap();
                    assert_eq!(single[p - 1], ys[j], "{bk:?}/t{threads}/p{p}: rhs {j}");
                }
            }
        }
    }
}

#[test]
fn gauss_seidel_and_kaczmarz_identical_across_backends() {
    // GS divides by the diagonal, so restrict to families with a
    // guaranteed nonzero diagonal (the stencil generators).
    for (name, a) in
        [("stencil5", gen::stencil2d_5pt(14, 14)), ("stencil9", gen::stencil2d_9pt(12, 10))]
    {
        let n = a.nrows();
        let b = vec![1.0; n];
        for threads in [1usize, 4] {
            let backends = ops(&a, threads);
            let mut gs: Vec<(Backend, Vec<f64>)> = Vec::new();
            let mut kz: Vec<(Backend, Vec<f64>)> = Vec::new();
            for (bk, op) in &backends {
                let mut x = vec![0.0; n];
                for _ in 0..20 {
                    op.gauss_seidel(&b, &mut x).unwrap();
                }
                gs.push((*bk, x));
                let mut x = vec![0.0; n];
                for _ in 0..20 {
                    op.kaczmarz(&b, &mut x).unwrap();
                }
                kz.push((*bk, x));
            }
            for (bk, x) in &gs[1..] {
                assert_eq!(&gs[0].1, x, "{name}/t{threads}: GS {:?} vs {bk:?}", gs[0].0);
            }
            for (bk, x) in &kz[1..] {
                assert_eq!(&kz[0].1, x, "{name}/t{threads}: KZ {:?} vs {bk:?}", kz[0].0);
            }
            // and the sweeps actually converge toward A x = b, checked
            // entirely in logical order against the original matrix
            let res = |x: &[f64]| -> f64 {
                let ax = a.spmv_ref(x);
                ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt()
            };
            let res0 = (n as f64).sqrt(); // residual of x = 0
            assert!(res(&gs[0].1) < 0.5 * res0, "{name}/t{threads}: GS residual");
            assert!(res(&kz[0].1) < 0.9 * res0, "{name}/t{threads}: KZ residual");
        }
    }
}

#[test]
fn three_term_matches_manual_recurrence() {
    let a = gen::graphene(8, 8);
    let n = a.nrows();
    let (sigma, tau, rho) = (0.4, -0.1, -1.0);
    let z_prev: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
    let z0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).cos()).collect();
    // manual recurrence with the reference SpMV, all in logical order
    let mut want = Vec::new();
    let (mut u, mut v) = (z_prev.clone(), z0.clone());
    for _ in 0..3 {
        let av = a.spmv_ref(&v);
        let z: Vec<f64> = (0..n).map(|i| sigma * av[i] + tau * v[i] + rho * u[i]).collect();
        want.push(z.clone());
        u = v;
        v = z;
    }
    let mut results: Vec<(Backend, Vec<Vec<f64>>)> = Vec::new();
    for (bk, op) in ops(&a, 2) {
        let zs = op.three_term(&z_prev, &z0, sigma, tau, rho, 3).unwrap();
        assert_eq!(zs.len(), 3);
        for k in 0..3 {
            let err = op::rel_err(&want[k], &zs[k]);
            assert!(err <= 1e-9, "{bk:?}: step {} err {err:.2e}", k + 1);
        }
        results.push((bk, zs));
    }
    for (bk, zs) in &results[1..] {
        assert_eq!(&results[0].1, zs, "three-term {:?} vs {bk:?}", results[0].0);
    }
}

#[test]
fn logical_order_is_invariant_to_internal_permutations() {
    let a = gen::delaunay_like(9, 9, 3);
    let n = a.nrows();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let want = a.spmv_ref(&x);
    // with and without RCM the logical-order answer is the same function
    for rcm in [true, false] {
        let op = Operator::build(&a, OpConfig::new().threads(3).rcm(rcm)).unwrap();
        let mut b = vec![0.0; n];
        op.symmspmv(&x, &mut b).unwrap();
        assert!(op::rel_err(&want, &b) < 1e-9, "rcm={rcm}");
        // round trip through executor numbering is lossless
        assert_eq!(op.unpermute(&op.permute(&x)), x);
        // the handle's own reference agrees with the original-order one
        assert!(op::rel_err(&want, &op.spmv_ref(&x)) < 1e-12, "rcm={rcm}");
    }
}

#[test]
fn shared_pool_serves_multiple_operators() {
    use race::pool::WorkerPool;
    use std::sync::Arc;
    let pool = Arc::new(WorkerPool::new(2));
    let mats = [gen::stencil2d_5pt(10, 10), gen::graphene(6, 6)];
    let ops: Vec<Operator> = mats
        .iter()
        .map(|a| {
            Operator::build(a, OpConfig::new().threads(2).shared_pool(pool.clone())).unwrap()
        })
        .collect();
    for (a, op) in mats.iter().zip(&ops) {
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i % 5) as f64) * 0.5 - 1.0).collect();
        let mut b = vec![0.0; n];
        op.symmspmv(&x, &mut b).unwrap();
        let want = a.spmv_ref(&x);
        assert!(op::rel_err(&want, &b) < 1e-9);
        let ys = op.powers(&x, 2).unwrap();
        assert!(op::rel_err(&op.powers_ref(&x, 2)[1], &ys[1]) < 1e-9);
    }
}

#[test]
fn facade_guards_and_helpers() {
    let a = gen::stencil2d_5pt(8, 8);
    let op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
    // p = 0 is a structured error, not a panic
    assert!(op.powers(&[1.0; 64], 0).is_err());
    assert!(op.prepare_powers(3).is_ok());
    assert!(op.mpk_with(2, 4 << 10).is_ok());
    // facade accessors expose the pieces benches compose manually
    assert!(op.eta() > 0.0 && op.eta() <= 1.0);
    assert_eq!(op.upper().nrows(), 64);
    assert_eq!(op.total_perm().len(), 64);
    assert!(op.program().nsteps() >= 1);
    // the op::upper helper covers schedules not owned by an Operator
    let u = op::upper(&a);
    assert_eq!(u.nrows(), 64);
    // non-symmetric input is rejected at build time
    let mut coo = race::sparse::Coo::new(3);
    coo.push(0, 1, 1.0);
    for i in 0..3 {
        coo.push(i, i, 2.0);
    }
    assert!(Operator::build(&coo.to_csr(), OpConfig::new()).is_err());
}
