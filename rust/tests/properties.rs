//! Property-based invariants (DESIGN.md §Key invariants) over randomly
//! generated symmetric matrices, using the in-tree prop driver.

use race::color::{abmc_schedule, greedy_coloring, mc_schedule, verify_coloring, verify_schedule};
use race::gen::XorShift64;
use race::graph;
use race::kernels;
use race::race::{verify_race_tree, RaceConfig, RaceEngine};
use race::util::prop::{arb_symmetric, check};

fn rand_x(rng: &mut XorShift64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect()
}

#[test]
fn prop_rcm_is_bijection_and_preserves_structure() {
    check("rcm bijection", 30, |rng| {
        let a = arb_symmetric(rng, 30, 200);
        let perm = graph::rcm(&a);
        if !graph::is_permutation(&perm) {
            return Err("not a permutation".into());
        }
        let b = a.permute_symmetric(&perm);
        if b.nnz() != a.nnz() {
            return Err("nnz changed".into());
        }
        if !b.is_symmetric() {
            return Err("symmetry lost".into());
        }
        // row sums are permutation-invariant
        let ones = vec![1.0; a.nrows()];
        let sa = a.spmv_ref(&ones);
        let sb = b.spmv_ref(&ones);
        for (old, &new) in perm.iter().enumerate() {
            if (sa[old] - sb[new as usize]).abs() > 1e-9 {
                return Err(format!("row sum mismatch at {old}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_levels_partition_and_match_bfs() {
    check("levels partition", 25, |rng| {
        let a = arb_symmetric(rng, 30, 150);
        let (levels, nl) = graph::bfs_levels_all(&a, 0);
        let mut counts = vec![0usize; nl];
        for &l in &levels {
            if l as usize >= nl {
                return Err("level out of range".into());
            }
            counts[l as usize] += 1;
        }
        if counts.iter().sum::<usize>() != a.nrows() {
            return Err("levels don't partition".into());
        }
        // adjacency: neighbours differ by at most 1 level (within an island)
        for v in 0..a.nrows() {
            let (cols, _) = a.row(v);
            for &c in cols {
                let d = (levels[v] as i64 - levels[c as usize] as i64).abs();
                if d > 1 && d != 2 && d != 3 {
                    // islands are offset by +2, so cross-island "edges"
                    // cannot exist at all; within an island d <= 1.
                    return Err(format!("BFS level jump {d} on edge {v}-{c}"));
                }
                if d > 1 {
                    return Err(format!("edge crosses islands?! {v}-{c}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_greedy_d2_coloring_valid() {
    check("greedy d2", 20, |rng| {
        let a = arb_symmetric(rng, 20, 120);
        let c = greedy_coloring(&a, 2, None);
        if !verify_coloring(&a, &c, 2) {
            return Err("invalid distance-2 coloring".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mc_abmc_schedules_valid() {
    check("schedules valid", 15, |rng| {
        let a = arb_symmetric(rng, 30, 150);
        for sched in [mc_schedule(&a, 2), abmc_schedule(&a, 8 + rng.next_below(16), 2)] {
            if !graph::is_permutation(&sched.perm) {
                return Err("schedule perm invalid".into());
            }
            let ap = a.permute_symmetric(&sched.perm);
            if !verify_schedule(&ap, &sched) {
                return Err("schedule violates distance-2".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_race_tree_valid_and_partitions() {
    check("race tree", 15, |rng| {
        let a = arb_symmetric(rng, 40, 200);
        let threads = 2 + rng.next_below(7);
        let cfg = RaceConfig { threads, dist: 2, ..Default::default() };
        let eng = RaceEngine::build(&a, &cfg).map_err(|e| e.to_string())?;
        if !graph::is_permutation(&eng.perm) {
            return Err("perm invalid".into());
        }
        if !verify_race_tree(&eng) {
            return Err("distance-2 sibling violation".into());
        }
        // leaves partition rows
        let mut covered = vec![false; a.nrows()];
        for l in eng.leaves() {
            let nd = &eng.tree[l as usize];
            for r in nd.start..nd.end {
                if covered[r as usize] {
                    return Err("leaf overlap".into());
                }
                covered[r as usize] = true;
            }
        }
        if !covered.iter().all(|&c| c) {
            return Err("leaves don't cover all rows".into());
        }
        let eta = eng.efficiency();
        if !(eta > 0.0 && eta <= 1.0 + 1e-9) {
            return Err(format!("eta out of range: {eta}"));
        }
        Ok(())
    });
}

#[test]
fn prop_all_executors_agree() {
    check("executors agree", 12, |rng| {
        let a = arb_symmetric(rng, 40, 160);
        let n = a.nrows();
        let x = rand_x(rng, n);
        let want = a.spmv_ref(&x);
        let tol = |w: f64| 1e-9 * (1.0 + w.abs());

        // serial + locks + private on natural order
        let upper = a.upper_triangle();
        let mut b1 = vec![0.0; n];
        kernels::symmspmv_serial(&upper, &x, &mut b1);
        let mut b2 = vec![0.0; n];
        kernels::symmspmv_locks(&upper, &x, &mut b2, 4);
        let mut b3 = vec![0.0; n];
        kernels::symmspmv_private(&upper, &x, &mut b3, 3);
        for i in 0..n {
            if (b1[i] - want[i]).abs() > tol(want[i]) {
                return Err(format!("serial row {i}"));
            }
            if (b2[i] - want[i]).abs() > tol(want[i]) {
                return Err(format!("locks row {i}"));
            }
            if (b3[i] - want[i]).abs() > tol(want[i]) {
                return Err(format!("private row {i}"));
            }
        }

        // RACE
        let cfg = RaceConfig { threads: 2 + rng.next_below(6), ..Default::default() };
        let eng = RaceEngine::build(&a, &cfg).map_err(|e| e.to_string())?;
        let up_r = eng.permuted_matrix().upper_triangle();
        let xp = race::coordinator::permute_vec(&x, &eng.perm);
        let mut br = vec![0.0; n];
        kernels::symmspmv_race(&eng, &up_r, &xp, &mut br);
        for (old, &new) in eng.perm.iter().enumerate() {
            if (br[new as usize] - want[old]).abs() > tol(want[old]) {
                return Err(format!("race row {old}"));
            }
        }

        // MC + ABMC
        for sched in [mc_schedule(&a, 2), abmc_schedule(&a, 12, 2)] {
            let ap = a.permute_symmetric(&sched.perm);
            let up = ap.upper_triangle();
            let xp = race::coordinator::permute_vec(&x, &sched.perm);
            let mut bc = vec![0.0; n];
            kernels::symmspmv_color(&sched, &up, &xp, &mut bc, 4);
            for (old, &new) in sched.perm.iter().enumerate() {
                if (bc[new as usize] - want[old]).abs() > tol(want[old]) {
                    return Err(format!("color row {old}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_unchecked_kernel_equals_checked() {
    // §Perf: the bounds-check-free hot path must be bit-identical to the
    // checked reference on every matrix family.
    check("unchecked == checked", 20, |rng| {
        let a = arb_symmetric(rng, 20, 150);
        let upper = a.upper_triangle();
        let n = a.nrows();
        let x = rand_x(rng, n);
        let mut b1 = vec![0.0; n];
        kernels::symmspmv_range_checked(&upper, &x, &mut b1, 0, n);
        let mut b2 = vec![0.0; n];
        race::kernels::symmspmv_range_unchecked(&upper, &x, &mut b2, 0, n);
        if b1 != b2 {
            return Err("unchecked kernel diverges from checked".into());
        }
        Ok(())
    });
}

#[test]
fn prop_upper_triangle_diag_leads() {
    check("upper triangle", 20, |rng| {
        let a = arb_symmetric(rng, 10, 120);
        let u = a.upper_triangle();
        u.validate().map_err(|e| e)?;
        for r in 0..u.nrows() {
            let (cols, _) = u.row(r);
            if cols[0] as usize != r {
                return Err(format!("row {r}: diag not first"));
            }
            if cols.iter().any(|&c| (c as usize) < r) {
                return Err(format!("row {r}: lower entry present"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ell_pack_matches_reference() {
    check("ell pack", 15, |rng| {
        let a = arb_symmetric(rng, 16, 100);
        let block = [4usize, 8, 16][rng.next_below(3)];
        let pack = race::sparse::SymmEllPack::from_csr(&a, block);
        if pack.n % block != 0 {
            return Err("padding not block-aligned".into());
        }
        let x = rand_x(rng, a.nrows());
        let got = pack.apply_ref(&pack.pad_x(&x));
        let want = a.spmv_ref(&x);
        for i in 0..a.nrows() {
            if (got[i] as f64 - want[i]).abs() > 1e-2 * (1.0 + want[i].abs()) {
                return Err(format!("row {i}: {} vs {}", got[i], want[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mm_roundtrip() {
    check("matrixmarket roundtrip", 10, |rng| {
        let a = arb_symmetric(rng, 10, 80);
        let dir = std::env::temp_dir().join("race_prop_mm");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let p = dir.join(format!("m{}.mtx", rng.next_u64()));
        race::sparse::write_matrix_market(&p, &a, true).map_err(|e| e.to_string())?;
        let b = race::sparse::read_matrix_market(&p).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&p);
        if a != b {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}
