//! End-to-end tests of the serve subsystem: a real TCP server, the
//! newline-delimited JSON protocol, request budgets and graceful
//! shutdown, plus service-level request batches. The request/response
//! shapes exercised here are the ones documented in
//! `docs/SERVE_PROTOCOL.md` — when a field changes, change both.

use race::serve::{MatvecService, ServeOptions, Server};
use race::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn opts(specs: &[&str]) -> ServeOptions {
    ServeOptions {
        matrices: specs.iter().map(|s| s.to_string()).collect(),
        threads: 2,
        addr: "127.0.0.1:0".to_string(),
        small: true,
        ..Default::default()
    }
}

/// Full TCP round trip: matvec, MPK, structured error, stats — then the
/// request budget runs out and the server shuts down gracefully.
#[test]
fn tcp_roundtrip_with_request_budget() {
    let mut o = opts(&["stencil2d:8x8"]);
    o.max_requests = Some(4);
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let ones = vec![1.0; 64];

    // 1: matvec — 5-pt stencil rows sum to 1, so A·ones = ones
    writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let b = j.get("b").and_then(|v| v.as_f64_arr()).expect("b array");
    assert_eq!(b.len(), 64);
    assert!(b.iter().all(|v| (v - 1.0).abs() < 1e-9), "{line}");
    assert_eq!(j.get("batch").and_then(Json::as_f64), Some(1.0));

    // 2: MPK — A² ones = ones too
    writer.write_all(format!("{{\"x\": {ones:?}, \"p\": 2}}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let y = j.get("y").and_then(|v| v.as_f64_arr()).expect("y array");
    assert!(y.iter().all(|v| (v - 1.0).abs() < 1e-9), "{line}");

    // 3: structured error for a wrong-length vector
    writer.write_all(b"{\"x\": [1, 2, 3]}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(
        j.get("error").and_then(|e| e.get("code")),
        Some(&Json::Str("bad_request".into())),
        "{line}"
    );

    // 4: stats — last budgeted request; the server stops afterwards
    writer.write_all(b"{\"stats\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let stats = j.get("stats").expect("stats object");
    assert_eq!(stats.get("requests").and_then(Json::as_f64), Some(4.0));
    assert_eq!(stats.get("errors").and_then(Json::as_f64), Some(1.0));

    // budget exhausted: run() returns and the connection closes
    handle.join().unwrap();
    line.clear();
    let n = reader.read_line(&mut line).unwrap_or(0);
    assert_eq!(n, 0, "connection should be closed after shutdown: {line:?}");
}

/// `{"shutdown": true}` stops the server without a request budget.
#[test]
fn tcp_shutdown_request_stops_server() {
    let server = Server::bind(&opts(&["stencil2d:6x6"])).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"shutdown\": true}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("shutting_down"), Some(&Json::Bool(true)), "{line}");
    handle.join().unwrap();
}

/// Shutdown *drains*: a request still being handled when `{"shutdown"}`
/// lands on another connection is answered in full. The server shuts
/// only the read side of live connections — the write path stays open
/// until every handler thread is joined (`docs/RELIABILITY.md`) — so
/// the first client must read one complete, correct response line and
/// then a clean EOF, never a truncated line or a wedged socket.
#[test]
fn tcp_shutdown_drains_in_flight_request() {
    let server = Server::bind(&opts(&["stencil2d:24x24"])).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    // warm round trip: proves this connection's handler loop is live
    // before racing it against the shutdown
    let ones = vec![1.0; 576];
    writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"b\""), "{line}");

    // a solve long enough that the shutdown usually lands mid-batch
    let (_, a) = race::coordinator::resolve_matrix("stencil2d:24x24", true).unwrap();
    let n = a.nrows();
    let x_true: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 11) as f64 * 0.25 - 1.0).collect();
    let rhs = a.spmv_ref(&x_true);
    let req = format!("{{\"solve\": {{\"rhs\": {rhs:?}, \"method\": \"cg\", \"tol\": 1e-11}}}}\n");
    writer.write_all(req.as_bytes()).unwrap();

    // second client: give the solve a moment to be picked up, then stop
    // the server while it is (most likely) still iterating
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let s = TcpStream::connect(addr).unwrap();
        let mut w = s.try_clone().unwrap();
        let mut r = BufReader::new(s);
        w.write_all(b"{\"shutdown\": true}\n").unwrap();
        let mut ack = String::new();
        r.read_line(&mut ack).unwrap();
        assert!(ack.contains("shutting_down"), "{ack}");
    });

    // the in-flight solve is drained, not cut: a full answer arrives
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("converged"), Some(&Json::Bool(true)), "{line}");
    let x = j.get("x").and_then(|v| v.as_f64_arr()).expect("x array");
    for i in 0..n {
        assert!(
            (x[i] - x_true[i]).abs() < 1e-6 * (1.0 + x_true[i].abs()),
            "drained solve must still be correct at row {i}: {} vs {}",
            x[i],
            x_true[i]
        );
    }

    killer.join().unwrap();
    handle.join().unwrap();
    // after the drain barrier the connection closes cleanly
    line.clear();
    let nread = reader.read_line(&mut line).unwrap_or(0);
    assert_eq!(nread, 0, "connection must close after drain: {line:?}");
}

/// Two matrices registered on one server; requests route by name and the
/// non-finite guard answers a structured error.
#[test]
fn tcp_multi_matrix_routing_and_nonfinite_guard() {
    let mut o = opts(&["stencil2d:8x8", "graphene:6x6"]);
    o.max_requests = Some(3);
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let graphene_n = server.service().entries()[1].n;
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    // route to the second matrix by name
    let x = vec![0.5; graphene_n];
    writer
        .write_all(format!("{{\"x\": {x:?}, \"matrix\": \"graphene:6x6\"}}\n").as_bytes())
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert!(j.get("b").is_some(), "{line}");

    // unknown matrix name
    writer.write_all(b"{\"x\": [1], \"matrix\": \"nope\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown_matrix"), "{line}");

    // non-finite input (1e999 overflows to +inf during JSON parsing)
    let huge = format!("{{\"x\": [{}1e999]}}\n", "1, ".repeat(63));
    writer.write_all(huge.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("nonfinite_input"), "{line}");

    handle.join().unwrap();
}

/// Concurrent clients: every request answered correctly; the stats
/// counters account for every vector exactly once.
#[test]
fn tcp_concurrent_clients_batch() {
    let mut o = opts(&["stencil2d:10x10"]);
    o.max_requests = Some(12);
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let mut clients = Vec::new();
    for t in 0..12usize {
        clients.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let x = vec![(t + 1) as f64; 100];
            writer.write_all(format!("{{\"x\": {x:?}}}\n").as_bytes()).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(line.trim()).unwrap();
            let b = j.get("b").and_then(|v| v.as_f64_arr()).expect("b array");
            // rows sum to 1 -> b == x
            assert!(b.iter().all(|v| (v - (t + 1) as f64).abs() < 1e-9), "{line}");
            j.get("batch").and_then(Json::as_f64).unwrap() as usize
        }));
    }
    let sizes: Vec<usize> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    assert!(sizes.iter().all(|&s| s >= 1));
    handle.join().unwrap();
}

/// Service-level batch call: the batched answer equals request-at-a-time
/// answers (the bench relies on this API).
#[test]
fn service_batch_equals_singles() {
    let svc = MatvecService::build(&opts(&["spin:6"])).unwrap();
    let n = svc.entries()[0].n;
    let xs: Vec<Vec<f64>> = (0..4)
        .map(|j| (0..n).map(|i| ((i * (j + 3) + 1) % 7) as f64 * 0.4 - 1.2).collect())
        .collect();
    let batched = svc.matvec_batch(None, &xs).unwrap();
    for (j, x) in xs.iter().enumerate() {
        let (single, _, _) = svc.matvec(None, x).unwrap();
        for i in 0..n {
            assert!(
                (batched[j][i] - single[i]).abs() <= 1e-12 * (1.0 + single[i].abs()),
                "rhs {j} row {i}"
            );
        }
    }
}

/// Full TCP round trip of the solve endpoint (`docs/SERVE_PROTOCOL.md`
/// §solve): a CG solve and a mixed-precision solve over the wire, then a
/// structured error for an unknown method.
#[test]
fn tcp_solve_roundtrip() {
    let mut o = opts(&["stencil2d:8x8"]);
    o.max_requests = Some(4);
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // rhs = A * x_true so the answer is checkable row by row
    let (_, a) = race::coordinator::resolve_matrix("stencil2d:8x8", true).unwrap();
    let n = a.nrows();
    let x_true: Vec<f64> = (0..n).map(|i| ((i * 5 + 2) % 9) as f64 * 0.3 - 1.2).collect();
    let rhs = a.spmv_ref(&x_true);

    for method in ["cg", "mixed"] {
        let body = format!("{{\"rhs\": {rhs:?}, \"method\": \"{method}\", \"tol\": 1e-9}}");
        let req = format!("{{\"solve\": {body}}}\n");
        writer.write_all(req.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("converged"), Some(&Json::Bool(true)), "{method}: {line}");
        assert_eq!(j.get("method"), Some(&Json::Str(method.to_string())));
        assert!(j.get("iterations").and_then(Json::as_f64).unwrap() >= 1.0);
        let x = j.get("x").and_then(|v| v.as_f64_arr()).expect("x array");
        for i in 0..n {
            assert!(
                (x[i] - x_true[i]).abs() < 1e-6 * (1.0 + x_true[i].abs()),
                "{method} row {i}: {} vs {}",
                x[i],
                x_true[i]
            );
        }
    }

    // structured error for a bogus method
    writer
        .write_all(format!("{{\"solve\": {{\"rhs\": {rhs:?}, \"method\": \"qr\"}}}}\n").as_bytes())
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(
        j.get("error").and_then(|e| e.get("code")),
        Some(&Json::Str("bad_request".to_string())),
        "{line}"
    );

    // stats shows the solves; this is also the budget's last request
    writer.write_all(b"{\"stats\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let stats = j.get("stats").expect("stats");
    assert_eq!(stats.get("solves").and_then(Json::as_f64), Some(2.0), "{line}");
    assert!(stats.get("solve_iterations").and_then(Json::as_f64).unwrap() >= 2.0);
    handle.join().unwrap();
}

/// `{"metrics": true}` over the wire: Prometheus-style text survives the
/// one-line JSON protocol and reflects the traffic, and the stats
/// superset carries the latency percentiles and error-by-code counters.
#[test]
fn tcp_metrics_exposition_roundtrip() {
    let mut o = opts(&["stencil2d:8x8"]);
    o.max_requests = Some(4);
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let ones = vec![1.0; 64];

    // one success + one error to give the counters something to count
    writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"b\""), "{line}");
    writer.write_all(b"{\"x\": [1, 2]}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("bad_request"), "{line}");

    // Prometheus text rides inside a JSON string (newlines escaped)
    writer.write_all(b"{\"metrics\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let text = match j.get("metrics") {
        Some(Json::Str(t)) => t.clone(),
        other => panic!("expected metrics text, got {other:?} in {line}"),
    };
    assert!(text.lines().count() > 10, "{text}");
    assert!(text.contains("race_requests_total 3"), "{text}");
    assert!(text.contains("race_matvec_requests_total 1"), "{text}");
    assert!(text.contains("race_error_responses_total{code=\"bad_request\"} 1"), "{text}");
    assert!(text.contains("race_request_duration_seconds_count{kind=\"matvec\"} 1"), "{text}");
    assert!(text.contains("race_matrix_storage_info{matrix=\"stencil2d:8x8\""), "{text}");

    // the stats superset: historical keys intact, new telemetry present
    writer.write_all(b"{\"stats\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let stats = j.get("stats").expect("stats");
    assert_eq!(stats.get("requests").and_then(Json::as_f64), Some(4.0));
    assert_eq!(stats.get("errors").and_then(Json::as_f64), Some(1.0));
    let by = stats.get("errors_by_code").expect("errors_by_code");
    assert_eq!(by.get("bad_request").and_then(Json::as_f64), Some(1.0));
    let lat = stats.get("latency_ms").and_then(|l| l.get("matvec")).expect("latency_ms.matvec");
    assert_eq!(lat.get("count").and_then(Json::as_f64), Some(1.0));
    assert!(lat.get("p99_ms").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(stats.get("uptime_seconds").and_then(Json::as_f64).unwrap() > 0.0);
    handle.join().unwrap();
}
