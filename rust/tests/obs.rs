//! Integration tests for the observability layer (`race::obs` + the
//! pool's per-worker timing slots): the per-worker compute/wait accounts
//! must reconcile with wall time on a real 4-thread pool run, the
//! disabled instrumentation path must cost nothing measurable, span
//! nesting must survive threads, histogram percentiles must interpolate
//! deterministically, and the Chrome-trace export must round-trip
//! through the JSON parser.

use race::obs;
use race::obs::hist::Hist;
use race::pool::{StepProgram, WorkUnit, WorkerPool};
use std::time::{Duration, Instant};

/// Busy-wait for `d` so per-unit compute is real CPU time the timing
/// slots can see (sleep would park the thread and undercount compute).
fn spin(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// A synthetic program: `nsteps` steps of `nunits` one-row units each
/// (`end > start` keeps [`StepProgram::from_steps`] from dropping them).
fn synthetic_program(nsteps: u32, nunits: u32) -> StepProgram {
    let steps = (0..nsteps)
        .map(|_| {
            (0..nunits).map(|i| WorkUnit { start: i, end: i + 1, power: 0 }).collect::<Vec<_>>()
        })
        .collect();
    StepProgram::from_steps(steps)
}

/// Tentpole check: with obs enabled, a 4-thread pool run fills the
/// per-worker per-step timing slots so that each worker's compute+wait
/// total reconciles with the job's wall time, and the derived imbalance
/// of a perfectly uniform schedule is near 1. The same test then pins
/// the disabled-path overhead (satellite: "within noise of an
/// uninstrumented baseline") — both halves share the global recorder, so
/// they live in one `#[test]` and cannot race sibling tests.
#[test]
fn pool_timing_slots_reconcile_with_wall_time_and_disabled_path_is_free() {
    let pool = WorkerPool::new(4);
    let prog = synthetic_program(3, 4);
    assert_eq!(prog.nsteps(), 3);
    let unit_ms = 5u64;

    obs::set_enabled(true);
    obs::recorder().drain();
    pool.execute(&prog, |_u| spin(Duration::from_millis(unit_ms)));
    let report = pool.take_exec_report().expect("enabled execute records a report");
    let events = obs::recorder().drain();
    obs::set_enabled(false);

    assert_eq!(report.threads, 4);
    assert_eq!(report.nsteps, 3);
    assert_eq!(report.compute_ns.len(), 4);
    assert_eq!(report.wait_ns.len(), 4);
    assert!(report.wall_ns > 0);

    // Every worker sweeps exactly one 5 ms unit per step, so total
    // compute must cover most of the 12-unit budget (scheduler noise and
    // clock granularity eat the rest).
    let budget_ns = 3 * 4 * unit_ms * 1_000_000;
    let total_compute: u64 = report.compute_ns.iter().sum();
    assert!(
        total_compute >= budget_ns * 8 / 10,
        "compute {total_compute} ns < 80% of budget {budget_ns} ns"
    );

    // Per-worker accounting closes: compute + barrier wait covers the
    // wall time up to thread start-up latency, and never exceeds it by
    // more than clock jitter.
    for w in 0..4 {
        let accounted = report.compute_ns[w] + report.wait_ns[w];
        assert!(
            accounted as f64 >= 0.6 * report.wall_ns as f64,
            "worker {w} accounted {accounted} ns of wall {} ns",
            report.wall_ns
        );
        assert!(
            accounted as f64 <= 1.10 * report.wall_ns as f64,
            "worker {w} over-accounted {accounted} ns of wall {} ns",
            report.wall_ns
        );
    }

    // A uniform schedule is balanced: imbalance = max/mean per-worker
    // compute stays near 1 (generous ceiling for CI-noise spikes).
    assert!(report.imbalance >= 1.0, "imbalance {} < 1", report.imbalance);
    assert!(report.imbalance < 2.0, "uniform schedule imbalanced: {}", report.imbalance);
    assert!(report.step_imbalance >= 1.0);
    assert!((0.0..=1.0).contains(&report.idle_frac));

    // The publisher also drops a `pool.execute` span on the timeline.
    assert!(
        events.iter().any(|e| e.name == "pool.execute"),
        "no pool.execute span among {} events",
        events.len()
    );

    // Overhead guard: a disabled span is one relaxed load — no clock
    // read, no allocation, nothing recorded. 200k calls must be
    // indistinguishable from an empty loop (sub-microsecond per call by
    // a wide CI margin) and must leave the buffer untouched.
    let len_before = obs::recorder().len();
    let t0 = Instant::now();
    for i in 0..200_000u64 {
        let _sp = obs::span("guard.noop");
        std::hint::black_box(i);
    }
    let disabled = t0.elapsed();
    assert_eq!(obs::recorder().len(), len_before, "disabled spans recorded events");
    assert!(disabled < Duration::from_millis(500), "200k disabled spans took {disabled:?}");

    // And the disabled pool path stays the fast path: re-running the
    // same job with obs off must not leave a report behind.
    pool.execute(&prog, |_u| spin(Duration::from_micros(50)));
    assert!(pool.take_exec_report().is_none(), "disabled execute recorded a report");
}

/// Span nesting survives threads: each thread gets its own stable tid
/// and its own depth counter, and children complete before parents.
#[test]
fn spans_nest_per_thread_on_a_local_recorder() {
    let rec = std::sync::Arc::new(obs::Recorder::new(true));
    {
        let _outer = rec.span("build");
        let _inner = rec.span_detail("build.rcm", || "bw=7".to_string());
        let rec2 = rec.clone();
        std::thread::spawn(move || {
            let _other = rec2.span("exec.symmspmv");
            spin(Duration::from_millis(1));
        })
        .join()
        .unwrap();
    }
    let mut events = rec.drain();
    assert_eq!(events.len(), 3);
    // completion order: the worker thread's span and the inner span both
    // finish before the outer guard drops
    assert_eq!(events.last().unwrap().name, "build");
    assert_eq!(events.last().unwrap().depth, 1);
    events.sort_by_key(|e| e.name);
    let [outer, inner, other] = match events.as_slice() {
        [a, b, c] => [a, b, c],
        _ => unreachable!(),
    };
    assert_eq!((outer.name, inner.name, other.name), ("build", "build.rcm", "exec.symmspmv"));
    // the spawned thread nests independently: depth restarts at 1 there
    assert_eq!(inner.depth, 2);
    assert_eq!(other.depth, 1);
    assert_ne!(other.tid, outer.tid, "threads must get distinct tids");
    assert_eq!(inner.tid, outer.tid);
    assert_eq!(inner.detail.as_deref(), Some("bw=7"));
}

/// Histogram percentiles are deterministic: bucket selection follows
/// Prometheus `le` semantics and quantiles interpolate linearly inside
/// the chosen bucket.
#[test]
fn hist_percentiles_interpolate_deterministically() {
    let h = Hist::latency();
    // 90 fast observations (1 µs, first bucket) and 10 slow (1 ms).
    for _ in 0..90 {
        h.observe(1_000);
    }
    for _ in 0..10 {
        h.observe(1_000_000);
    }
    assert_eq!(h.count(), 100);
    // p50 lands mid-first-bucket: rank 50 of 90 in (0, 1_000].
    let p50 = h.quantile(0.50);
    assert!((p50 - 1_000.0 * 50.0 / 90.0).abs() < 1e-6, "p50 = {p50}");
    // p95 lands in the slow bucket (512_000, 1_024_000]: rank 95 is the
    // 5th of its 10 observations -> halfway through the bucket.
    let p95 = h.quantile(0.95);
    assert!((p95 - (512_000.0 + 0.5 * 512_000.0)).abs() < 1e-6, "p95 = {p95}");
    // p99 -> 9th of 10: 90% through the bucket.
    let p99 = h.quantile(0.99);
    assert!((p99 - (512_000.0 + 0.9 * 512_000.0)).abs() < 1e-6, "p99 = {p99}");
    assert_eq!(h.max(), 1_000_000);
    let mean = h.mean();
    assert!((mean - (90.0 * 1_000.0 + 10.0 * 1_000_000.0) / 100.0).abs() < 1e-9, "mean = {mean}");

    // Size histogram: batch sizes land in doubling buckets, overflow is
    // attributed to the recorded max.
    let s = Hist::sizes();
    for v in [1u64, 8, 8, 5000] {
        s.observe(v);
    }
    let c = s.bucket_counts();
    assert_eq!(c[0], 1); // <= 1
    assert_eq!(c[3], 2); // <= 8
    assert_eq!(*c.last().unwrap(), 1); // overflow
    assert_eq!(s.quantile(1.0), 5000.0);
}

/// The whole hardware-counter surface degrades deterministically under
/// `RACE_HWC=0`: probe, group open, IMC open, pool requests, roofline
/// rows and the baseline fingerprint all report `disabled_by_env` —
/// never an error. All env manipulation lives in this single `#[test]`
/// (the other tests in this binary never read `RACE_HWC`, so the
/// process-global env can't race).
#[test]
fn hwc_surface_degrades_under_disabled_env() {
    use race::obs::hwc;

    std::env::set_var("RACE_HWC", "0");

    // capability and both open paths answer the stable reason code
    let cap = hwc::probe();
    assert!(!cap.is_available());
    assert_eq!(cap.reason(), hwc::REASON_DISABLED);
    assert_eq!(hwc::HwcGroup::open(hwc::Scope::Thread).err(), Some(hwc::REASON_DISABLED));
    assert_eq!(hwc::HwcGroup::open(hwc::Scope::Process).err(), Some(hwc::REASON_DISABLED));
    assert_eq!(hwc::ImcCounters::open().err(), Some(hwc::REASON_DISABLED));

    // a pool asked for counters still executes and simply omits the
    // measured columns from its report (no set_enabled here: the global
    // recorder belongs to the reconcile test; a report only appears if
    // that test happens to have it on, and then it must carry no cycles)
    let pool = WorkerPool::new(2);
    pool.set_hwc(true);
    let prog = synthetic_program(2, 2);
    let hits = std::sync::atomic::AtomicU32::new(0);
    pool.execute(&prog, |_u| {
        hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    });
    assert_eq!(hits.load(std::sync::atomic::Ordering::Relaxed), 4);
    if let Some(report) = pool.take_exec_report() {
        assert!(report.hwc_cycles.is_none(), "disabled env must not publish cycles");
        assert!(report.hwc_instructions.is_none());
    }

    // a roofline row built from the degraded reason keeps the JSON shape
    let m = race::machine::ivb();
    let row = race::obs::roofline::RooflineRow::new("symmspmv", 0.01, 1e8, 2e7, &m)
        .measured_unavailable(cap.reason());
    let j = row.to_json();
    assert_eq!(j.get("measured"), Some(&race::util::json::Json::Str("unavailable".into())));
    assert_eq!(
        j.get("measured_reason"),
        Some(&race::util::json::Json::Str("disabled_by_env".into()))
    );

    // and the machine fingerprint records the same verdict, so a
    // bench-diff across hosts can see why measured columns are missing
    let fp = race::obs::baseline::fingerprint(Some(&m));
    assert_eq!(
        fp.get("hwc"),
        Some(&race::util::json::Json::Str("disabled_by_env".into()))
    );

    std::env::remove_var("RACE_HWC");
    // with the override gone the probe answers whatever the host allows,
    // and any degraded reason still comes from the stable catalogue
    match hwc::probe() {
        hwc::Capability::Available => {}
        hwc::Capability::Unavailable(r) => assert!(hwc::REASONS.contains(&r), "{r}"),
    }
}

/// The Chrome-trace export writes JSON the crate's own parser accepts,
/// with one complete event (`ph: "X"`) per span and microsecond stamps.
#[test]
fn chrome_trace_round_trips_through_json() {
    use race::util::json::Json;
    let rec = obs::Recorder::new(true);
    {
        let _outer = rec.span("build");
        let _inner = rec.span_detail("build.rcm", || "bw=3".to_string());
        spin(Duration::from_millis(1));
    }
    let events = rec.drain();
    let path = std::env::temp_dir().join("race_obs_trace_roundtrip.json");
    let path = path.to_str().expect("temp path is utf-8");
    obs::trace::write_chrome_trace(path, &events).expect("write trace file");
    let text = std::fs::read_to_string(path).expect("read trace file");
    std::fs::remove_file(path).ok();
    let doc = Json::parse(&text).expect("trace file parses");
    let evs = match doc.get("traceEvents") {
        Some(Json::Arr(v)) => v,
        other => panic!("traceEvents missing or not an array: {other:?}"),
    };
    assert_eq!(evs.len(), 2);
    for ev in evs {
        assert!(matches!(ev.get("ph"), Some(Json::Str(s)) if s == "X"));
        assert!(ev.get("ts").and_then(|j| j.as_f64()).is_some());
        assert!(ev.get("dur").and_then(|j| j.as_f64()).is_some());
        assert!(ev.get("name").is_some() && ev.get("cat").is_some());
    }
    // the annotated span carries its detail into args
    assert!(text.contains("bw=3"), "detail lost: {text}");
}
