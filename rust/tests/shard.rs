//! Sharded-tier properties: `Backend::Sharded` must be **bit-identical**
//! to `Backend::Serial` on every generator family, for shards ∈ {1, 2, 4}
//! × threads ∈ {1, 2, 4} — whichever domain a call lands on, it executes
//! the same compiled step program over a bit-wise replica of the same
//! storage, so placement can never change a result. On top: explicit
//! routing (`symmspmv_multi_routed`) agrees shard by shard, the sticky
//! router's placement/steal policy holds, and a `--shards 2` server
//! answers the full protocol over TCP.

mod common;

use common::{families, THREADS};
use race::gen;
use race::op::{Backend, OpConfig, Operator};
use race::serve::{MatvecService, ServeOptions, Server};
use race::shard::Router;
use race::sparse::Csr;
use race::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

const SHARDS: [usize; 3] = [1, 2, 4];

fn build(a: &Csr, backend: Backend, threads: usize) -> Operator {
    Operator::build(a, OpConfig::new().threads(threads).backend(backend).cache_bytes(8 << 10))
        .unwrap()
}

#[test]
fn symmspmv_bit_identical_to_serial_across_shards_and_threads() {
    for (name, a) in families() {
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 13 + 5) % 23) as f64 * 0.2 - 2.0).collect();
        for threads in THREADS {
            let serial = build(&a, Backend::Serial, threads);
            let mut want = vec![0.0; n];
            serial.symmspmv(&x, &mut want).unwrap();
            for shards in SHARDS {
                let op = build(&a, Backend::Sharded { shards }, threads);
                // several calls, so the round-robin cursor visits every
                // shard's pinned pool and replica
                for round in 0..shards.max(2) {
                    let mut b = vec![0.0; n];
                    op.symmspmv(&x, &mut b).unwrap();
                    assert_eq!(
                        want, b,
                        "{name}/t{threads}/s{shards} round {round}: not bit-identical"
                    );
                }
            }
        }
    }
}

#[test]
fn powers_bit_identical_to_serial_across_shards() {
    for (name, a) in families() {
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.15 - 0.9).collect();
        for threads in [1usize, 2] {
            let serial = build(&a, Backend::Serial, threads);
            for p in [1usize, 3] {
                let want = serial.powers(&x, p).unwrap();
                for shards in [2usize, 4] {
                    let op = build(&a, Backend::Sharded { shards }, threads);
                    let ys = op.powers(&x, p).unwrap();
                    assert_eq!(want, ys, "{name}/t{threads}/s{shards}/p{p}: not bit-identical");
                }
            }
        }
    }
}

#[test]
fn solve_bit_identical_to_serial_under_sharding() {
    for (name, a) in [("stencil5", gen::stencil2d_5pt(16, 13)), ("graphene", gen::graphene(8, 8))]
    {
        let n = a.nrows();
        let rhs: Vec<f64> = (0..n).map(|i| ((i * 5 + 1) % 11) as f64 * 0.3 - 1.0).collect();
        let cfg = race::solver::SolveConfig::new().tol(1e-9);
        let serial = build(&a, Backend::Serial, 2);
        let want = serial.solve(&rhs, &cfg).unwrap();
        assert!(want.converged, "{name}: serial reference must converge");
        for shards in [2usize, 4] {
            let op = build(&a, Backend::Sharded { shards }, 2);
            let got = op.solve(&rhs, &cfg).unwrap();
            assert!(got.converged, "{name}/s{shards}");
            assert_eq!(want.iterations, got.iterations, "{name}/s{shards}: iteration history");
            assert_eq!(want.x, got.x, "{name}/s{shards}: solution not bit-identical");
        }
    }
}

#[test]
fn multi_rhs_fanout_matches_singles_bitwise() {
    let m = 5usize;
    for (name, a) in families() {
        let n = a.nrows();
        let xs: Vec<Vec<f64>> = (0..m)
            .map(|j| (0..n).map(|i| ((i * (j + 3) + 2 * j) % 17) as f64 * 0.3 - 1.4).collect())
            .collect();
        let op = build(&a, Backend::Sharded { shards: 2 }, 2);
        // the batch fans its columns out across both replicas
        let mut bs: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
        op.symmspmv_multi(&xs, &mut bs).unwrap();
        for j in 0..m {
            let mut b = vec![0.0; n];
            op.symmspmv(&xs[j], &mut b).unwrap();
            assert_eq!(b, bs[j], "{name}: rhs {j} diverges under fan-out");
        }
    }
}

#[test]
fn explicit_routing_is_placement_independent() {
    let a = gen::stencil2d_5pt(16, 13);
    let n = a.nrows();
    let m = 3usize;
    let xs: Vec<Vec<f64>> = (0..m)
        .map(|j| (0..n).map(|i| ((i * (j + 2) + 1) % 19) as f64 * 0.25 - 1.5).collect())
        .collect();
    let shards = 3usize;
    let op = build(&a, Backend::Sharded { shards }, 2);
    // fan-out result (no placement preference)
    let mut want: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
    op.symmspmv_multi(&xs, &mut want).unwrap();
    // sticky whole-batch placement on each shard in turn: every replica
    // must produce the same bits
    for s in 0..shards {
        let mut bs: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
        op.symmspmv_multi_routed(&xs, &mut bs, Some(s)).unwrap();
        assert_eq!(want, bs, "shard {s}: routed batch diverges");
    }
    // MPK routes the same way
    let yw = op.powers_multi(&xs, 2).unwrap();
    for s in 0..shards {
        let ys = op.powers_multi_routed(&xs, 2, Some(s)).unwrap();
        assert_eq!(yw, ys, "shard {s}: routed MPK batch diverges");
    }
}

#[test]
fn router_is_sticky_then_steals_under_skew() {
    let r = Router::new(3, 2);
    // sticky: key 4 -> home shard 1, repeatedly
    for _ in 0..5 {
        let t = r.place(4);
        assert_eq!(t.shard(), 1);
        assert!(!t.stolen);
    }
    // saturate the home queue, keep the tickets alive
    let _h1 = r.place(4);
    let _h2 = r.place(4);
    assert_eq!(r.depth(1), 2);
    // skew: the next placement steals from the least-loaded shard
    let t = r.place(4);
    assert!(t.stolen);
    assert_eq!(t.shard(), 0, "ties break to the lowest id");
    assert_eq!(r.steals(0), 1);
    drop(t);
    // skew gone (queue drained below the cap): sticky again
    drop(_h1);
    let t = r.place(4);
    assert_eq!(t.shard(), 1);
    assert!(!t.stolen);
}

/// RAII property: router queue depth can never leak — a panic that
/// unwinds past held tickets must release exactly their slots and no
/// others. Without this, one panicking batch leader would permanently
/// inflate a shard's depth and the router would steal away from a
/// perfectly healthy shard forever (`docs/RELIABILITY.md`).
#[test]
fn router_tickets_release_depth_on_panic_unwind() {
    for shards in [1usize, 2, 3, 5] {
        let r = Router::new(shards, 2);
        let mut total_placed = 0u64;
        for key in 0..23usize {
            // survivors held across the panic: their depth must not be
            // touched by the unwinding placements
            let survivor = r.place(key);
            let before: Vec<usize> = (0..shards).map(|s| r.depth(s)).collect();
            let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut held = Vec::new();
                for i in 0..4usize {
                    // mix the sticky path and the health-filtered path
                    held.push(if i % 2 == 0 {
                        r.place(key + i)
                    } else {
                        r.place_healthy(key + i, |s| s != key % shards.max(1))
                    });
                }
                panic!("unwinding with {} tickets held", held.len());
            }));
            assert!(unwound.is_err(), "closure must panic");
            total_placed += 4;
            // every panicked ticket released its slot; the survivor kept its
            let after: Vec<usize> = (0..shards).map(|s| r.depth(s)).collect();
            assert_eq!(before, after, "shards {shards} key {key}: depth leaked across unwind");
            drop(survivor);
        }
        assert!((0..shards).all(|s| r.depth(s) == 0), "all tickets dropped: depth must be 0");
        // the unwound placements still counted as placements
        let placed: u64 = (0..shards).map(|s| r.placements(s)).sum();
        assert_eq!(placed, total_placed + 23, "23 survivors + 4 per key unwound");
    }
}

/// The same property under concurrency: threads race placements and
/// panics against each other; once every thread has unwound, depth is
/// zero on every shard.
#[test]
fn router_depth_drains_after_concurrent_panics() {
    let shards = 4usize;
    let r = std::sync::Arc::new(Router::new(shards, 2));
    let mut handles = Vec::new();
    for t in 0..8usize {
        let r = r.clone();
        handles.push(std::thread::spawn(move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut held = Vec::new();
                for i in 0..50usize {
                    held.push(r.place(t * 31 + i * 7));
                    if held.len() > 3 {
                        held.remove(0); // steady churn: drop the oldest
                    }
                    if t % 2 == 0 && i == 29 {
                        panic!("chaos unwind with {} tickets held", held.len());
                    }
                }
            }));
            out.is_err()
        }));
    }
    let panicked =
        handles.into_iter().map(|h| h.join().unwrap()).filter(|&p| p).count();
    assert_eq!(panicked, 4, "every even-numbered thread unwinds");
    for s in 0..shards {
        assert_eq!(r.depth(s), 0, "shard {s}: depth must drain to zero after unwinds");
    }
    // placements counted: 4 panicking threads place 30 each, 4 run to 50
    let placed: u64 = (0..shards).map(|s| r.placements(s)).sum();
    assert_eq!(placed, 4 * 30 + 4 * 50);
}

/// A `--shards 2` server over real TCP: matvec, MPK, solve and the
/// per-shard telemetry all answer correctly (the CI `shard-smoke` job
/// runs this file).
#[test]
fn tcp_sharded_server_end_to_end() {
    let o = ServeOptions {
        matrices: vec!["stencil2d:8x8".to_string()],
        threads: 2,
        shards: 2,
        addr: "127.0.0.1:0".to_string(),
        small: true,
        max_requests: Some(5),
        ..Default::default()
    };
    let server = Server::bind(&o).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let ones = vec![1.0; 64];

    // matvec: 5-pt stencil rows sum to 1, so A·ones = ones
    writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let b = j.get("b").and_then(|v| v.as_f64_arr()).expect("b array");
    assert!(b.iter().all(|v| (v - 1.0).abs() < 1e-9), "{line}");

    // MPK: A² ones = ones too
    writer.write_all(format!("{{\"x\": {ones:?}, \"p\": 2}}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let y = j.get("y").and_then(|v| v.as_f64_arr()).expect("y array");
    assert!(y.iter().all(|v| (v - 1.0).abs() < 1e-9), "{line}");

    // solve: rhs = ones has the solution ones
    writer
        .write_all(format!("{{\"solve\": {{\"rhs\": {ones:?}, \"tol\": 1e-9}}}}\n").as_bytes())
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("converged"), Some(&Json::Bool(true)), "{line}");

    // metrics: the race_shard_* gauges ride the exposition
    writer.write_all(b"{\"metrics\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let text = match j.get("metrics") {
        Some(Json::Str(t)) => t.clone(),
        other => panic!("expected metrics text, got {other:?} in {line}"),
    };
    assert!(text.contains("race_shard_info{shard=\"0\""), "{text}");
    assert!(text.contains("race_shard_info{shard=\"1\""), "{text}");
    assert!(text.contains("race_shard_placements_total"), "{text}");
    assert!(text.contains("race_shard_batch_seconds"), "{text}");

    // stats: per-shard rows present, all traffic accounted to shard 0
    // (one matrix -> home shard 0; no concurrency -> no steals)
    writer.write_all(b"{\"stats\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let stats = j.get("stats").expect("stats");
    let rows = match stats.get("shards") {
        Some(Json::Arr(v)) => v,
        other => panic!("expected shard rows, got {other:?} in {line}"),
    };
    assert_eq!(rows.len(), 2, "{line}");
    let placed: f64 =
        rows.iter().map(|r| r.get("placements").and_then(Json::as_f64).unwrap()).sum();
    assert!(placed >= 3.0, "matvec + mpk + solve iterations all placed: {line}");
    for r in rows {
        assert_eq!(r.get("depth").and_then(Json::as_f64), Some(0.0), "drained: {line}");
    }
    handle.join().unwrap();
}

/// The sharded service answers bit-identically to the flat service —
/// through the public service API (what the serve e2e layer rides on).
#[test]
fn sharded_service_batches_match_flat_service() {
    let base = ServeOptions {
        matrices: vec!["delaunay:10x10".to_string()],
        threads: 2,
        addr: "127.0.0.1:0".to_string(),
        small: true,
        ..Default::default()
    };
    let flat = MatvecService::build(&base).unwrap();
    let mut o = base.clone();
    o.shards = 2;
    let sharded = MatvecService::build(&o).unwrap();
    let n = flat.entries()[0].n;
    let xs: Vec<Vec<f64>> = (0..6)
        .map(|j| (0..n).map(|i| ((i * (j + 2)) % 11) as f64 * 0.2 - 1.0).collect())
        .collect();
    assert_eq!(
        flat.matvec_batch(None, &xs).unwrap(),
        sharded.matvec_batch(None, &xs).unwrap(),
        "sharded batch must be bit-identical to the flat pool"
    );
}
