//! XLA runtime integration: load the AOT artifacts produced by
//! `make artifacts` and check the Pallas SymmSpMV against the native Rust
//! kernel. Compiled only with the `xla` feature, and skipped (with a loud
//! message) unless `RACE_XLA_TESTS=1` is set and the artifacts exist —
//! `cargo test -q` on a clean checkout must pass without `make artifacts`.
#![cfg(feature = "xla")]

use race::gen;
use race::kernels;
use race::runtime::{artifacts_dir, xla_tests_enabled, XlaRuntime};
use race::sparse::SymmEllPack;

fn artifact(name: &str) -> Option<std::path::PathBuf> {
    if !xla_tests_enabled() {
        eprintln!("SKIP: set RACE_XLA_TESTS=1 to run PJRT integration tests");
        return None;
    }
    let p = artifacts_dir().join(format!("{name}.hlo.txt"));
    if p.exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifact {} missing (run `make artifacts`)", p.display());
        None
    }
}

#[test]
fn symmspmv_artifact_matches_native() {
    let Some(path) = artifact("symmspmv") else { return };
    let a = gen::stencil2d_5pt(64, 64);
    let pack = SymmEllPack::from_csr(&a, 64);
    assert_eq!((pack.n, pack.wu, pack.wl), (4096, 3, 2), "artifact shape contract");

    let mut rt = XlaRuntime::cpu().unwrap();
    rt.load_artifact("symmspmv", &path).unwrap();

    let x: Vec<f64> = (0..a.nrows()).map(|i| (i as f64 * 0.11).cos()).collect();
    let xp = pack.pad_x(&x);
    let nn = pack.n as i64;
    let out = rt
        .execute_mixed(
            "symmspmv",
            &[(&pack.vals_u, &[nn, pack.wu as i64]), (&xp, &[nn])],
            &[
                (&pack.cols_u, &[nn, pack.wu as i64]),
                (&pack.idx_l, &[nn, pack.wl as i64]),
                (&pack.cols_l, &[nn, pack.wl as i64]),
            ],
        )
        .unwrap()
        .remove(0);

    let upper = a.upper_triangle();
    let mut want = vec![0.0f64; a.nrows()];
    kernels::symmspmv_serial(&upper, &x, &mut want);
    for i in 0..a.nrows() {
        let e = (out[i] as f64 - want[i]).abs() / (1.0 + want[i].abs());
        assert!(e < 1e-4, "row {i}: {} vs {}", out[i], want[i]);
    }
}

#[test]
fn cg_step_artifact_reduces_residual() {
    let Some(path) = artifact("cg_step") else { return };
    let a = gen::stencil2d_5pt(64, 64);
    let n = a.nrows();
    let pack = SymmEllPack::from_csr(&a, 64);
    let mut rt = XlaRuntime::cpu().unwrap();
    rt.load_artifact("cg_step", &path).unwrap();

    // state: x=0, r=p=rhs, rs = |rhs|^2
    let rhs = vec![1.0f32; pack.n];
    let x0 = vec![0.0f32; pack.n];
    let rs0: f32 = rhs.iter().map(|v| v * v).sum();
    let nn = pack.n as i64;
    let mut x = x0;
    let mut r = rhs.clone();
    let mut p = rhs.clone();
    let mut rs = rs0;
    for _ in 0..30 {
        let out = rt
            .execute_mixed(
                "cg_step",
                &[
                    (&pack.vals_u, &[nn, pack.wu as i64]),
                    (&x, &[nn]),
                    (&r, &[nn]),
                    (&p, &[nn]),
                    (std::slice::from_ref(&rs), &[]),
                ],
                &[
                    (&pack.cols_u, &[nn, pack.wu as i64]),
                    (&pack.idx_l, &[nn, pack.wl as i64]),
                    (&pack.cols_l, &[nn, pack.wl as i64]),
                ],
            )
            .unwrap();
        // cg_step returns the 4-tuple (x', r', p', rs')
        assert_eq!(out.len(), 4, "expected 4-tuple from cg_step");
        let mut it = out.into_iter();
        x = it.next().unwrap();
        r = it.next().unwrap();
        p = it.next().unwrap();
        rs = it.next().unwrap()[0];
    }
    assert!(rs < 0.01 * rs0, "CG must reduce the residual: {rs} vs {rs0}");
    // solution approaches ones on the interior
    let errs = x[..n].iter().filter(|v| (**v - 1.0).abs() > 0.2).count();
    assert!(errs < n / 4, "solution far from ones: {errs}/{n}");
}
