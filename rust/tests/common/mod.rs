//! Shared test corpus and assertion helpers for the integration tests
//! (`op.rs`, `pack.rs`, `shard.rs`, `solver.rs`, `kernels.rs`). Each test
//! binary pulls this in with `mod common;` and uses the subset it needs —
//! hence the blanket `dead_code` allow.
#![allow(dead_code)]

use race::gen;
use race::op::Backend;
use race::solver;
use race::sparse::Csr;

/// Thread counts every backend sweep covers.
pub const THREADS: [usize; 3] = [1, 2, 4];

/// The in-process backends (the sharded tier composes these and is
/// swept separately where a test needs it).
pub const BACKENDS: [Backend; 3] = [Backend::Serial, Backend::Scoped, Backend::Pool];

/// One matrix per generator family — the corpus the facade/shard property
/// tests sweep (small enough for a backends × threads × families product).
pub fn families() -> Vec<(&'static str, Csr)> {
    vec![
        ("stencil5", gen::stencil2d_5pt(16, 13)),
        ("stencil9", gen::stencil2d_9pt(12, 11)),
        ("paperstencil", gen::race_paper_stencil(16, 16)),
        ("spin", gen::spin_chain_xxz(8, gen::SpinKind::XXZ)),
        ("graphene", gen::graphene(8, 8)),
        ("delaunay", gen::delaunay_like(10, 10, 7)),
        ("band", gen::dense_band(150, 30, 120, 2)),
    ]
}

/// The full generator corpus (stencils, quantum chains, lattices,
/// irregular meshes, dense bands, random graphs) the storage-pack tests
/// round-trip — a strict superset of [`families`].
pub fn pack_families() -> Vec<(&'static str, Csr)> {
    vec![
        ("stencil5", gen::stencil2d_5pt(16, 13)),
        ("stencil9", gen::stencil2d_9pt(12, 11)),
        ("stencil3d7", gen::stencil3d_7pt(6, 6, 6)),
        ("stencil3d27", gen::stencil3d_27pt(5, 5, 5)),
        ("paperstencil", gen::race_paper_stencil(16, 16)),
        ("spin", gen::spin_chain_xxz(8, gen::SpinKind::XXZ)),
        ("hubbard", gen::hubbard_chain(4, 4.0)),
        ("boson", gen::free_boson_chain(4, 3)),
        ("anderson", gen::anderson3d(4, 2.0, 7)),
        ("graphene", gen::graphene(8, 8)),
        ("delaunay", gen::delaunay_like(10, 10, 7)),
        ("band", gen::dense_band(150, 30, 120, 2)),
        ("random", gen::random_symmetric(120, 8, 11)),
    ]
}

/// SPD test corpus: diagonally dominant generators as-is, the rest
/// certified SPD via a Gershgorin shift (`solver::make_spd`).
pub fn spd_families() -> Vec<(&'static str, Csr)> {
    let shifted = |a: &Csr| solver::make_spd(a, 0.02).0;
    vec![
        ("stencil2d_5pt", gen::stencil2d_5pt(16, 16)),
        ("stencil2d_9pt", gen::stencil2d_9pt(12, 10)),
        ("stencil3d_27pt", gen::stencil3d_27pt(5, 5, 4)),
        ("graphene", gen::graphene(8, 8)),
        ("delaunay", shifted(&gen::delaunay_like(12, 12, 3))),
        ("dense_band", shifted(&gen::dense_band(220, 18, 50, 7))),
        ("spin_chain", shifted(&gen::spin_chain_xxz(7, gen::SpinKind::XXZ))),
    ]
}

/// Deterministic non-trivial input vector (the pack tests' convention).
pub fn test_vector(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 7 + 3) % 23) as f64 * 0.21 - 2.0).collect()
}

/// `rhs = A x_true` for a known deterministic `x_true`, so solver checks
/// can verify against the true residual directly.
pub fn rhs_for(a: &Csr) -> Vec<f64> {
    let n = a.nrows();
    let xs: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.25 - 1.5).collect();
    a.spmv_ref(&xs)
}

/// Backend-independent relative residual `‖Ax − rhs‖₂ / ‖rhs‖₂` computed
/// with the reference SpMV.
pub fn true_rel_residual(a: &Csr, rhs: &[f64], x: &[f64]) -> f64 {
    let ax = a.spmv_ref(x);
    let num: f64 = ax.iter().zip(rhs).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt();
    let den: f64 = rhs.iter().map(|v| v * v).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

/// Assert two f64 slices are **bitwise** identical, reporting the first
/// differing row with both bit patterns — the crate's load-bearing
/// equality, used everywhere "bit-identical" is claimed.
pub fn assert_bitwise(want: &[f64], got: &[f64], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length {} vs {}", want.len(), got.len());
    for i in 0..want.len() {
        assert!(
            want[i].to_bits() == got[i].to_bits(),
            "{ctx}: row {i}: {} ({:#018x}) vs {} ({:#018x})",
            want[i],
            want[i].to_bits(),
            got[i],
            got[i].to_bits()
        );
    }
}

/// Assert `got` is within a relative tolerance of `want` row by row
/// (the op-test convention: `|want − got| ≤ tol · (1 + |want|)`).
pub fn assert_close(want: &[f64], got: &[f64], tol: f64, ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length mismatch");
    for i in 0..want.len() {
        assert!(
            (want[i] - got[i]).abs() <= tol * (1.0 + want[i].abs()),
            "{ctx}: row {i}: {} vs {}",
            want[i],
            got[i]
        );
    }
}
