//! Traffic-compact storage properties: CSR → `CsrPack` round-trips over
//! every generator family, **bit-identical** f64 SymmSpMV / matrix-power
//! results between packed and CSR storage across all backends × threads
//! {1, 2, 4} × powers 1..4, single-precision (`ValPrec::F32`) tolerance
//! bounds, and the automatic CSR fallback when a pack would not pay.

mod common;

use common::{pack_families as families, test_vector, BACKENDS, THREADS};
use race::gen;
use race::op::{self, Backend, OpConfig, Operator, Storage};
use race::sparse::{Coo, CsrPack, PackKind, ValPrec};

#[test]
fn pack_round_trips_every_family() {
    for (name, a) in families() {
        let upper = a.upper_triangle();
        for prec in [ValPrec::F64, ValPrec::F32] {
            let pu = CsrPack::pack_upper(&upper, prec);
            pu.validate().unwrap_or_else(|e| panic!("{name}/upper/{prec:?}: {e}"));
            assert_eq!(pu.kind, PackKind::Upper);
            assert_eq!(pu.nnz(), upper.nnz(), "{name}: pack must store every nonzero");
            let pf = CsrPack::pack_full(&a, prec);
            pf.validate().unwrap_or_else(|e| panic!("{name}/full/{prec:?}: {e}"));
            assert_eq!(pf.nnz(), a.nnz());
            if prec == ValPrec::F64 {
                assert_eq!(pu.to_csr(), upper, "{name}: upper round-trip");
                assert_eq!(pf.to_csr(), a, "{name}: full round-trip");
            } else {
                // f32 packs round values; the structure must survive
                let (bu, bf) = (pu.to_csr(), pf.to_csr());
                assert_eq!(bu.col, upper.col, "{name}: upper f32 structure");
                assert_eq!(bf.col, a.col, "{name}: full f32 structure");
                for (w, g) in upper.val.iter().zip(&bu.val) {
                    assert_eq!(*g, *w as f32 as f64, "{name}: f32 value rounding");
                }
            }
        }
    }
}

#[test]
fn symmspmv_pack_bit_identical_to_csr_across_backends() {
    for (name, a) in families() {
        let n = a.nrows();
        let x = test_vector(n);
        for &threads in &THREADS {
            // CSR reference output per backend
            for &backend in &BACKENDS {
                let cfg = |s: Storage| OpConfig::new().threads(threads).backend(backend).storage(s);
                let csr = Operator::build(&a, cfg(Storage::Csr)).unwrap();
                let pack = Operator::build(&a, cfg(Storage::Pack)).unwrap();
                assert_eq!(csr.effective_storage(), Storage::Csr);
                let mut bc = vec![0.0; n];
                csr.symmspmv(&x, &mut bc).unwrap();
                let mut bp = vec![0.0; n];
                pack.symmspmv(&x, &mut bp).unwrap();
                assert_eq!(bc, bp, "{name}: t={threads} {backend:?} symmspmv pack != csr");
                // multi-RHS rides the same packs
                let xs: Vec<Vec<f64>> = (0..3)
                    .map(|j| (0..n).map(|i| ((i * (j + 2) + 5) % 13) as f64 * 0.3 - 1.7).collect())
                    .collect();
                let mut bsc: Vec<Vec<f64>> = vec![vec![0.0; n]; 3];
                let mut bsp: Vec<Vec<f64>> = vec![vec![0.0; n]; 3];
                csr.symmspmv_multi(&xs, &mut bsc).unwrap();
                pack.symmspmv_multi(&xs, &mut bsp).unwrap();
                assert_eq!(bsc, bsp, "{name}: t={threads} {backend:?} multi pack != csr");
            }
        }
    }
}

#[test]
fn powers_pack_bit_identical_to_csr_across_backends() {
    // a subset of families keeps the p-sweep tractable; coverage of the
    // remaining families comes from the symmspmv test above
    let mats = vec![
        ("stencil9", gen::stencil2d_9pt(12, 11)),
        ("spin", gen::spin_chain_xxz(8, gen::SpinKind::XXZ)),
        ("delaunay", gen::delaunay_like(10, 10, 7)),
    ];
    for (name, a) in mats {
        let n = a.nrows();
        let x = test_vector(n);
        for &threads in &THREADS {
            for &backend in &BACKENDS {
                let cfg = |s: Storage| {
                    OpConfig::new()
                        .threads(threads)
                        .backend(backend)
                        .storage(s)
                        .cache_bytes(8 << 10)
                };
                let csr = Operator::build(&a, cfg(Storage::Csr)).unwrap();
                let pack = Operator::build(&a, cfg(Storage::Pack)).unwrap();
                for p in 1..=4usize {
                    let yc = csr.powers(&x, p).unwrap();
                    let yp = pack.powers(&x, p).unwrap();
                    assert_eq!(yc, yp, "{name}: t={threads} {backend:?} p={p} powers");
                }
                // batched powers and the three-term recurrence too
                let xs: Vec<Vec<f64>> = (0..3)
                    .map(|j| (0..n).map(|i| ((i * (j + 3) + 1) % 11) as f64 * 0.25 - 1.1).collect())
                    .collect();
                let yc = csr.powers_multi(&xs, 3).unwrap();
                let yp = pack.powers_multi(&xs, 3).unwrap();
                assert_eq!(yc, yp, "{name}: t={threads} {backend:?} powers_multi");
                let z_prev = test_vector(n);
                let z0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).cos()).collect();
                let zc = csr.three_term(&z_prev, &z0, 0.4, -0.1, -1.0, 3).unwrap();
                let zp = pack.three_term(&z_prev, &z0, 0.4, -0.1, -1.0, 3).unwrap();
                assert_eq!(zc, zp, "{name}: t={threads} {backend:?} three_term");
            }
        }
    }
}

#[test]
fn f32_pack_stays_within_tolerance() {
    for (name, a) in families() {
        let n = a.nrows();
        let x = test_vector(n);
        let f64_op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
        let f32_op = Operator::build(
            &a,
            OpConfig::new().threads(2).storage(Storage::Pack).precision(ValPrec::F32),
        )
        .unwrap();
        let mut want = vec![0.0; n];
        f64_op.symmspmv(&x, &mut want).unwrap();
        let mut got = vec![0.0; n];
        f32_op.symmspmv(&x, &mut got).unwrap();
        let err = op::rel_err(&want, &got);
        assert!(err < 1e-5, "{name}: f32 symmspmv rel_err {err:.2e}");
        // power sweeps compound the matrix-entry rounding ~linearly in p
        let yw = f64_op.powers(&x, 4).unwrap();
        let yg = f32_op.powers(&x, 4).unwrap();
        let perr = op::rel_err(&yw[3], &yg[3]);
        assert!(perr < 1e-3, "{name}: f32 powers rel_err {perr:.2e}");
    }
}

#[test]
fn infeasible_pack_falls_back_to_csr() {
    // Without RCM, rows couple only to columns > 2^16 away, so every
    // off-diagonal escapes and the pack is bigger than CSR: the operator
    // must fall back to CSR storage and still answer correctly.
    let n = 70_000usize;
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 2.0 + (i % 3) as f64);
    }
    for i in 0..1_000 {
        coo.push_sym(i, i + 66_000, -0.5);
        coo.push_sym(i, i + 67_500, 0.25);
    }
    let a = coo.to_csr();
    let upper = a.upper_triangle();
    let pack = CsrPack::pack_upper(&upper, ValPrec::F64);
    assert_eq!(pack.escapes(), 2_000, "every off-diagonal must escape");
    assert!(!pack.feasible(), "escape-dominated pack must not pay");
    // threads(1) keeps the engine permutation at identity (single-leaf
    // tree), so the wide couplings actually reach the storage layer
    let op = Operator::build(
        &a,
        OpConfig::new().threads(1).backend(Backend::Serial).storage(Storage::Pack).rcm(false),
    )
    .unwrap();
    assert_eq!(op.effective_storage(), Storage::Csr, "must fall back");
    assert!(op.pack().is_none());
    let x = test_vector(n);
    let mut b = vec![0.0; n];
    op.symmspmv(&x, &mut b).unwrap();
    let want = op.spmv_ref(&x);
    assert!(op::rel_err(&want, &b) < 1e-9);
    // with RCM the same matrix re-bands and the pack becomes feasible
    let op_rcm = Operator::build(
        &a,
        OpConfig::new().threads(1).backend(Backend::Serial).storage(Storage::Pack),
    )
    .unwrap();
    assert_eq!(op_rcm.effective_storage(), Storage::Pack, "RCM makes deltas narrow");
    let mut b2 = vec![0.0; n];
    op_rcm.symmspmv(&x, &mut b2).unwrap();
    assert!(op::rel_err(&want, &b2) < 1e-9);
}

#[test]
fn escaped_entries_survive_the_operator_path() {
    // mostly-banded matrix with a few out-of-band couplings: the pack
    // stays feasible (escapes are rare) and must agree with CSR bitwise
    let n = 70_000usize;
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 2.0);
        if i + 1 < n {
            coo.push_sym(i, i + 1, -0.5);
        }
    }
    coo.push_sym(0, 66_000, -1.0);
    coo.push_sym(123, 69_000, 0.75);
    let a = coo.to_csr();
    // rcm(false) + threads(1) (identity engine permutation) keeps the
    // wide couplings wide, forcing real escapes on the operator path
    let cfg =
        |s: Storage| OpConfig::new().threads(1).backend(Backend::Serial).storage(s).rcm(false);
    let pack_op = Operator::build(&a, cfg(Storage::Pack)).unwrap();
    assert_eq!(pack_op.effective_storage(), Storage::Pack);
    let pk = pack_op.pack().unwrap();
    assert!(pk.escapes() >= 2, "wide couplings must escape");
    let csr_op = Operator::build(&a, cfg(Storage::Csr)).unwrap();
    let x = test_vector(n);
    let (mut bp, mut bc) = (vec![0.0; n], vec![0.0; n]);
    pack_op.symmspmv(&x, &mut bp).unwrap();
    csr_op.symmspmv(&x, &mut bc).unwrap();
    assert_eq!(bp, bc, "escape path must stay bit-identical");
}
