//! Edge cases and failure injection: degenerate matrices, pathological
//! graphs, invalid inputs, and robustness of every public entry point.

use race::color::{abmc_schedule, mc_schedule, verify_schedule};
use race::coordinator::{self, Method};
use race::gen;
use race::graph;
use race::kernels;
use race::machine;
use race::race::{RaceConfig, RaceEngine};
use race::sparse::{Coo, Csr};

/// A 1x1 matrix.
fn tiny() -> Csr {
    let mut coo = Coo::new(1);
    coo.push(0, 0, 3.0);
    coo.to_csr()
}

/// Diagonal-only matrix (no off-diagonal dependencies at all).
fn diagonal(n: usize) -> Csr {
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 2.0 + i as f64);
    }
    coo.to_csr()
}

/// Star graph: one hub connected to everything (a dense row).
fn star(n: usize) -> Csr {
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 10.0);
    }
    for i in 1..n {
        coo.push_sym(0, i, -1.0);
    }
    coo.to_csr()
}

#[test]
fn one_by_one_matrix() {
    let a = tiny();
    let cfg = RaceConfig { threads: 4, ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    let upper = eng.permuted_matrix().upper_triangle();
    let mut b = vec![0.0];
    kernels::symmspmv_race(&eng, &upper, &[2.0], &mut b);
    assert_eq!(b, vec![6.0]);
}

#[test]
fn diagonal_matrix_all_methods() {
    let a = diagonal(40);
    for method in [Method::Race, Method::Mc, Method::Abmc, Method::Serial] {
        let m = machine::ivb();
        let r = coordinator::run_pipeline("stencil2d:4x4", method, 2, &m, true).unwrap();
        assert!(r.max_rel_err < 1e-9);
    }
    // direct: diagonal SymmSpMV == scaling
    let upper = a.upper_triangle();
    let x: Vec<f64> = (0..40).map(|i| i as f64).collect();
    let mut b = vec![0.0; 40];
    kernels::symmspmv_serial(&upper, &x, &mut b);
    for i in 0..40 {
        assert_eq!(b[i], (2.0 + i as f64) * i as f64);
    }
}

#[test]
fn star_graph_dense_row() {
    // paper footnote 7: a dense row collapses the level structure to
    // N_l = 2 — parallelism exists but is minimal.
    let a = star(200);
    let (_, nl) = graph::bfs_levels_all(&a, 0);
    assert!(nl <= 3, "star graph must have <= 3 levels, got {nl}");
    let cfg = RaceConfig { threads: 8, ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    // correctness still holds even with terrible eta
    let upper = eng.permuted_matrix().upper_triangle();
    let x: Vec<f64> = (0..200).map(|i| (i as f64).sin()).collect();
    let xp = coordinator::permute_vec(&x, &eng.perm);
    let mut b = vec![0.0; 200];
    kernels::symmspmv_race(&eng, &upper, &xp, &mut b);
    let want = a.spmv_ref(&x);
    for (old, &new) in eng.perm.iter().enumerate() {
        assert!((b[new as usize] - want[old]).abs() < 1e-10);
    }
}

#[test]
fn disconnected_components() {
    // two independent grids in one matrix
    let g = gen::stencil2d_5pt(8, 8);
    let n = g.nrows();
    let mut coo = Coo::new(2 * n);
    for r in 0..n {
        let (cols, vals) = g.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(r, c as usize, v);
            coo.push(n + r, n + c as usize, v);
        }
    }
    let a = coo.to_csr();
    let cfg = RaceConfig { threads: 4, ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    assert!(race::race::verify_race_tree(&eng));
    let upper = eng.permuted_matrix().upper_triangle();
    let x = vec![1.0; 2 * n];
    let mut b = vec![0.0; 2 * n];
    kernels::symmspmv_race(&eng, &upper, &x, &mut b);
    // rows sum to 1 in each copy
    for v in &b {
        assert!((v - 1.0).abs() < 1e-10);
    }
}

#[test]
fn zero_threads_rejected() {
    let a = tiny();
    let cfg = RaceConfig { threads: 0, ..Default::default() };
    assert!(RaceEngine::build(&a, &cfg).is_err());
    let cfg = RaceConfig { dist: 0, ..Default::default() };
    assert!(RaceEngine::build(&a, &cfg).is_err());
}

#[test]
fn oversubscribed_threads() {
    // more threads than rows: must not panic, eta degrades gracefully
    let a = gen::stencil2d_5pt(4, 4);
    let cfg = RaceConfig { threads: 64, ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    assert!(eng.efficiency() > 0.0);
    let upper = eng.permuted_matrix().upper_triangle();
    let x = vec![1.0; 16];
    let mut b = vec![0.0; 16];
    kernels::symmspmv_race(&eng, &upper, &x, &mut b);
    for v in &b {
        assert!((v - 1.0).abs() < 1e-10);
    }
}

#[test]
fn schedules_on_dense_block() {
    // fully dense small matrix: every pair of rows conflicts; MC needs
    // n colors, ABMC one block per color — still valid, fully serial.
    let n = 12;
    let mut coo = Coo::new(n);
    for i in 0..n {
        for j in 0..n {
            coo.push(i, j, if i == j { 4.0 } else { -0.1 });
        }
    }
    let a = coo.to_csr();
    let mc = mc_schedule(&a, 2);
    assert_eq!(mc.phases.len(), n, "dense block needs n colors");
    let ap = a.permute_symmetric(&mc.perm);
    assert!(verify_schedule(&ap, &mc));
    let ab = abmc_schedule(&a, 4, 2);
    let ap2 = a.permute_symmetric(&ab.perm);
    assert!(verify_schedule(&ap2, &ab));
}

#[test]
fn pipeline_rejects_unknown_inputs() {
    let m = machine::ivb();
    assert!(coordinator::run_pipeline("nope:1x1", Method::Race, 2, &m, true).is_err());
    assert!("bogus".parse::<Method>().is_err());
    assert!("race".parse::<Method>().is_ok());
}

#[test]
fn mm_reader_rejects_nonsymmetric_for_pipeline() {
    let dir = std::env::temp_dir().join("race_edge");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("asym.mtx");
    std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 2 2.0\n")
        .unwrap();
    let m = machine::ivb();
    let res = coordinator::run_pipeline(p.to_str().unwrap(), Method::Race, 2, &m, true);
    assert!(res.is_err(), "asymmetric matrix must be rejected");
}

#[test]
fn json_parser_fuzz_does_not_panic() {
    use race::util::json::Json;
    let mut rng = gen::XorShift64::new(99);
    let charset: Vec<char> = "{}[]\",:0123456789.eE+-truefalsn ".chars().collect();
    for _ in 0..3000 {
        let len = rng.next_below(60);
        let s: String = (0..len).map(|_| charset[rng.next_below(charset.len())]).collect();
        let _ = Json::parse(&s); // must never panic
    }
}

#[test]
fn gs_race_on_anisotropic_grid() {
    let a0 = gen::stencil2d_9pt(15, 7);
    let cfg = RaceConfig { threads: 3, dist: 1, ..Default::default() };
    let eng = RaceEngine::build(&a0, &cfg).unwrap();
    let a = eng.permuted_matrix().clone();
    let b = vec![1.0; a.nrows()];
    let mut x = vec![0.0; a.nrows()];
    for _ in 0..400 {
        kernels::gauss_seidel_race(&eng, &a, &b, &mut x);
    }
    let ax = a.spmv_ref(&x);
    let res: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt();
    assert!(res < 1e-8, "GS residual {res}");
}

#[test]
fn dist1_engine_rejected_for_kaczmarz() {
    let a = gen::stencil2d_5pt(6, 6);
    let cfg = RaceConfig { threads: 2, dist: 1, ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    let a_perm = eng.permuted_matrix().clone();
    let b = vec![1.0; 36];
    let mut x = vec![0.0; 36];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        kernels::kaczmarz_race(&eng, &a_perm, &b, &mut x);
    }));
    assert!(result.is_err(), "distance-1 engine must be rejected for Kaczmarz");
}

#[test]
fn distance_k_greater_than_two() {
    // the engine's distance-k machinery is generic (§4.2): verify k = 3
    // and k = 4 trees keep same-color siblings distance-k independent.
    for k in [3usize, 4] {
        for (name, a) in [
            ("stencil", gen::stencil2d_5pt(24, 24)),
            ("graphene", gen::graphene(10, 10)),
        ] {
            let cfg = RaceConfig { threads: 4, dist: k, ..Default::default() };
            let eng = RaceEngine::build(&a, &cfg).unwrap();
            assert!(
                race::race::verify_race_tree(&eng),
                "{name}: distance-{k} violation"
            );
            // matvec still correct
            let upper = eng.permuted_matrix().upper_triangle();
            let x: Vec<f64> = (0..a.nrows()).map(|i| (i as f64 * 0.2).cos()).collect();
            let xp = coordinator::permute_vec(&x, &eng.perm);
            let mut b = vec![0.0; a.nrows()];
            kernels::symmspmv_race(&eng, &upper, &xp, &mut b);
            let want = a.spmv_ref(&x);
            for (old, &new) in eng.perm.iter().enumerate() {
                assert!((b[new as usize] - want[old]).abs() < 1e-10, "{name} k={k} row {old}");
            }
        }
    }
}

#[test]
fn ssor_pcg_on_anisotropic_problem() {
    let a0 = gen::stencil2d_9pt(20, 20);
    let cfg = RaceConfig { threads: 3, dist: 1, ..Default::default() };
    let eng = RaceEngine::build(&a0, &cfg).unwrap();
    let a = eng.permuted_matrix().clone();
    let upper = a.upper_triangle();
    let n = a.nrows();
    let rhs: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
    let mut x = vec![0.0; n];
    let a_ref = &a;
    let eng_ref = &eng;
    let res = kernels::pcg_solve(
        &mut |v, out| kernels::symmspmv_serial(&upper, v, out),
        &mut |r, z| kernels::ssor_precond(eng_ref, a_ref, r, z),
        &rhs,
        &mut x,
        1e-9,
        2000,
    );
    assert!(res.converged, "PCG iters={}", res.iterations);
    let ax = a.spmv_ref(&x);
    let rel = ax.iter().zip(&rhs).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt()
        / rhs.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(rel < 1e-7, "true residual {rel}");
}

#[test]
fn ablation_flags_change_tree() {
    let e = gen::corpus_entry("inline_1").unwrap();
    let a = (e.build)(true);
    let base = RaceConfig { threads: 12, ..Default::default() };
    let full = RaceEngine::build(&a, &base).unwrap();
    let norec =
        RaceEngine::build(&a, &RaceConfig { no_recursion: true, ..base.clone() }).unwrap();
    assert!(
        norec.node_count() <= full.node_count(),
        "no-recursion tree must not be larger"
    );
    // recursion must have been adding parallelism on this matrix
    assert!(norec.efficiency() <= full.efficiency() + 1e-9);
}
