//! MPK subsystem integration tests: level-blocked matrix powers must equal
//! `p` repeated reference SpMVs across every generator family, power and
//! thread count, and the blocked schedule must move strictly fewer bytes
//! per nonzero application than `p` naive sweeps.

use race::cachesim;
use race::coordinator::{self, permute_vec, Method};
use race::gen;
use race::kernels;
use race::machine;
use race::mpk::{powers_ref, MpkConfig, MpkPlan};
use race::race::{RaceConfig, RaceEngine};
use race::sparse::Csr;

fn families() -> Vec<(&'static str, Csr)> {
    vec![
        ("stencil2d", gen::stencil2d_5pt(24, 18)),
        ("spin_chain_xxz", gen::spin_chain_xxz(9, gen::SpinKind::XXZ)),
        ("graphene", gen::graphene(12, 12)),
        ("delaunay_like", gen::delaunay_like(14, 14, 7)),
        ("dense_band", gen::dense_band(400, 24, 300, 4)),
    ]
}

/// Assert `got` (permuted) equals `want` to 1e-9 vector-relative
/// tolerance (see [`race::mpk::rel_err_vs_ref`]).
fn assert_close_permuted(want: &[f64], got: &[f64], perm: &[u32], ctx: &str) {
    let err = race::mpk::rel_err_vs_ref(want, got, perm);
    assert!(err <= 1e-9, "{ctx}: vector-relative error {err:.2e}");
}

/// `mpk(p)` == `p` applications of `spmv_ref`, for all families,
/// p ∈ {1..4}, threads ∈ {1, 2, 4} — to 1e-9 relative tolerance.
#[test]
fn mpk_matches_repeated_spmv_ref() {
    for (name, a) in families() {
        let x: Vec<f64> = (0..a.nrows()).map(|i| ((i * 13 % 29) as f64) * 0.07 - 1.0).collect();
        for p in 1..=4usize {
            // small cache target so plans split into several blocks even at
            // test scale
            let cfg = MpkConfig { p, cache_bytes: 24 << 10 };
            let plan = MpkPlan::build(&a, &cfg)
                .unwrap_or_else(|e| panic!("{name} p={p}: plan build failed: {e}"));
            assert!(plan.verify(), "{name} p={p}: plan invariants violated");
            let want = powers_ref(&a, &x, p);
            let xp = permute_vec(&x, &plan.perm);
            for threads in [1usize, 2, 4] {
                let ys = kernels::mpk_powers(&plan, &xp, threads);
                assert_eq!(ys.len(), p);
                for (k, yk) in ys.iter().enumerate() {
                    let ctx = format!("{name} p={p} k={} threads={threads}", k + 1);
                    assert_close_permuted(&want[k], yk, &plan.perm, &ctx);
                }
            }
        }
    }
}

/// Plans built from an existing RACE engine's stage-0 levels are equally
/// correct (and share the level structure with the SymmSpMV engine).
#[test]
fn mpk_from_engine_correct() {
    for (name, a) in families() {
        let eng = RaceEngine::build(&a, &RaceConfig { threads: 4, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}: engine: {e}"));
        let cfg = MpkConfig { p: 3, cache_bytes: 16 << 10 };
        let plan = MpkPlan::from_engine(&a, &eng, &cfg).unwrap();
        assert!(plan.verify(), "{name}");
        let x: Vec<f64> = (0..a.nrows()).map(|i| (i as f64 * 0.37).sin()).collect();
        let want = powers_ref(&a, &x, 3);
        let xp = permute_vec(&x, &plan.perm);
        let ys = kernels::mpk_powers(&plan, &xp, 2);
        assert_close_permuted(&want[2], &ys[2], &plan.perm, name);
    }
}

/// Acceptance: cachesim reports strictly fewer bytes/nonzero for the
/// level-blocked sweep than for `p` naive sweeps — on a stencil AND a
/// graph matrix whose working set exceeds the cache.
#[test]
fn mpk_traffic_below_naive_on_stencil_and_graph() {
    for (name, a0) in [
        ("stencil2d:64x64", gen::stencil2d_5pt(64, 64)),
        ("delaunay:40x40", gen::delaunay_like(40, 40, 3)),
    ] {
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let p = 4;
        let m = machine::skx().under_pressure(a.crs_bytes(), 4);
        let cfg = MpkConfig { p, cache_bytes: m.effective_cache() / 2 };
        let plan = MpkPlan::build(&a, &cfg).unwrap();
        assert!(plan.nblocks() > 1, "{name}: expected a multi-block plan");
        let blocked = cachesim::measure_mpk_traffic(&plan, &m);
        // naive on the same level-permuted matrix: isolate blocking
        let naive = cachesim::measure_spmv_powers_traffic(plan.permuted_matrix(), p, &m);
        assert!(
            blocked.bytes_per_nnz_full < naive.bytes_per_nnz_full,
            "{name}: blocked {} must beat naive {} B/nnz-app",
            blocked.bytes_per_nnz_full,
            naive.bytes_per_nnz_full
        );
    }
}

/// MPK as a first-class pipeline method through the coordinator.
#[test]
fn mpk_pipeline_method() {
    let m = machine::skx();
    let r = coordinator::run_pipeline("stencil2d:32x32", Method::Mpk, 2, &m, true).unwrap();
    assert!(r.max_rel_err < 1e-9, "err={}", r.max_rel_err);
    assert!(r.traffic.bytes_total > 0);
    assert!(r.sim.gflops > 0.0);
    assert!(r.host_gflops > 0.0);
    // "mpk" parses as a method name
    let parsed: Method = "mpk".parse().unwrap();
    assert_eq!(parsed, Method::Mpk);
}

/// The three-term executor reproduces the step-by-step Chebyshev-style
/// recurrence (the chebyshev_filter example's chunked path).
#[test]
fn mpk_three_term_recurrence_roundtrip() {
    let a = gen::spin_chain_xxz(8, gen::SpinKind::XXZ);
    let n = a.nrows();
    let (sigma, tau, rho) = (0.31, -0.12, -1.0);
    let z_prev = vec![0.0; n];
    let z0: Vec<f64> = (0..n).map(|i| ((i * 2654435761usize) % 1000) as f64 / 500.0 - 1.0).collect();
    // unblocked reference
    let (mut u, mut v) = (z_prev.clone(), z0.clone());
    let mut want = Vec::new();
    for _ in 0..3 {
        let av = a.spmv_ref(&v);
        let w: Vec<f64> = (0..n).map(|i| sigma * av[i] + tau * v[i] + rho * u[i]).collect();
        want.push(w.clone());
        u = v;
        v = w;
    }
    let plan = MpkPlan::build(&a, &MpkConfig { p: 3, cache_bytes: 32 << 10 }).unwrap();
    let zs = kernels::mpk_three_term(
        &plan,
        &permute_vec(&z_prev, &plan.perm),
        &permute_vec(&z0, &plan.perm),
        sigma,
        tau,
        rho,
        2,
    );
    for k in 0..3 {
        assert_close_permuted(&want[k], &zs[k], &plan.perm, &format!("three-term k={k}"));
    }
}
