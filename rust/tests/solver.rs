//! Property tests of the solver subsystem: SPD generator families ×
//! backends × thread counts. Every solve must reach its tolerance
//! (checked against the backend-independent reference SpMV), the
//! mixed-precision solution must match the f64-only one, and the
//! preconditioned variants must not take more iterations than plain CG.

mod common;

use common::{rhs_for, spd_families, true_rel_residual};
use race::gen;
use race::op::{Backend, OpConfig, Operator};
use race::solver::{Method, SolveConfig};

#[test]
fn cg_converges_on_every_family_backend_and_thread_count() {
    for (name, a) in spd_families() {
        let rhs = rhs_for(&a);
        for backend in [Backend::Serial, Backend::Scoped, Backend::Pool] {
            for threads in [1usize, 2, 4] {
                let op = Operator::build(&a, OpConfig::new().threads(threads).backend(backend))
                    .unwrap();
                let cfg = SolveConfig::new().tol(1e-9).max_iter(3000);
                let sol = op.solve(&rhs, &cfg).unwrap();
                assert!(
                    sol.converged,
                    "{name}/{backend:?}/t{threads}: CG did not converge ({} iters, last {:?})",
                    sol.iterations,
                    sol.residuals.last()
                );
                let err = true_rel_residual(&a, &rhs, &sol.x);
                assert!(err <= 1e-8, "{name}/{backend:?}/t{threads}: residual {err:.3e}");
            }
        }
    }
}

#[test]
fn mixed_precision_matches_f64_solution_within_tolerance() {
    for (name, a) in spd_families() {
        let rhs = rhs_for(&a);
        let op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
        let f64_sol = op.solve(&rhs, &SolveConfig::new().tol(1e-10).max_iter(5000)).unwrap();
        let mixed = op
            .solve(&rhs, &SolveConfig::new().method(Method::Mixed).tol(1e-10).max_iter(5000))
            .unwrap();
        assert!(f64_sol.converged, "{name}: f64 CG did not converge");
        assert!(mixed.converged, "{name}: mixed did not converge");
        assert!(
            true_rel_residual(&a, &rhs, &mixed.x) <= 1e-9,
            "{name}: mixed residual too large"
        );
        let scale = f64_sol.x.iter().fold(0f64, |m, v| m.max(v.abs()));
        for i in 0..op.n() {
            assert!(
                (f64_sol.x[i] - mixed.x[i]).abs() <= 1e-5 * (1.0 + scale),
                "{name} row {i}: {} vs {}",
                f64_sol.x[i],
                mixed.x[i]
            );
        }
    }
}

#[test]
fn mixed_precision_splits_work_onto_the_f32_pack() {
    // on a pack-feasible matrix the refinement must actually run its
    // inner sweeps at low precision, and without stagnating
    let a = gen::stencil2d_5pt(24, 24);
    let rhs = rhs_for(&a);
    for threads in [1usize, 2, 4] {
        let op = Operator::build(&a, OpConfig::new().threads(threads)).unwrap();
        let sol =
            op.solve(&rhs, &SolveConfig::new().method(Method::Mixed).tol(1e-8)).unwrap();
        assert!(sol.converged && !sol.fell_back, "t{threads}: {:?}", sol.residuals);
        assert!(sol.used_f32, "t{threads}: f32 pack must be feasible for a stencil");
        assert!(sol.matvecs_f32 > 0 && sol.matvecs_f32 > sol.matvecs, "t{threads}");
    }
}

#[test]
fn preconditioned_variants_take_no_more_iterations_than_cg() {
    for (name, a) in spd_families() {
        let rhs = rhs_for(&a);
        let op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
        let tol = 1e-9;
        let plain = op.solve(&rhs, &SolveConfig::new().tol(tol).max_iter(5000)).unwrap();
        let jacobi = op
            .solve(&rhs, &SolveConfig::new().method(Method::JacobiCg).tol(tol).max_iter(5000))
            .unwrap();
        let ssor = op
            .solve(&rhs, &SolveConfig::new().method(Method::SsorCg).tol(tol).max_iter(5000))
            .unwrap();
        assert!(plain.converged && jacobi.converged && ssor.converged, "{name}");
        assert!(
            jacobi.iterations <= plain.iterations,
            "{name}: Jacobi-CG {} > CG {}",
            jacobi.iterations,
            plain.iterations
        );
        assert!(
            ssor.iterations <= plain.iterations,
            "{name}: SSOR-CG {} > CG {}",
            ssor.iterations,
            plain.iterations
        );
        assert!(ssor.precond_applies > 0 && jacobi.precond_applies > 0, "{name}");
    }
}

#[test]
fn chebyshev_converges_across_backends_with_gershgorin_bounds() {
    // diagonally dominant families certify their own spectrum interval
    for (name, a) in
        [("stencil2d_5pt", gen::stencil2d_5pt(16, 16)), ("graphene", gen::graphene(8, 8))]
    {
        let rhs = rhs_for(&a);
        for backend in [Backend::Serial, Backend::Scoped, Backend::Pool] {
            let op =
                Operator::build(&a, OpConfig::new().threads(2).backend(backend)).unwrap();
            let cfg = SolveConfig::new().method(Method::Chebyshev).tol(1e-8).max_iter(2000);
            let sol = op.solve(&rhs, &cfg).unwrap();
            assert!(sol.converged, "{name}/{backend:?}: {:?}", sol.residuals.last());
            let err = true_rel_residual(&a, &rhs, &sol.x);
            assert!(err <= 5e-8, "{name}/{backend:?}: residual {err:.3e}");
        }
    }
}

#[test]
fn solutions_are_bit_identical_across_backends() {
    // CG is a fixed sequence of SymmSpMVs, dots and axpys; since the
    // facade's SymmSpMV is bit-identical across backends, so is the
    // whole solve history
    let a = gen::stencil2d_9pt(14, 11);
    let rhs = rhs_for(&a);
    let solve = |backend: Backend, threads: usize| {
        let op = Operator::build(&a, OpConfig::new().threads(threads).backend(backend)).unwrap();
        op.solve(&rhs, &SolveConfig::new().tol(1e-10)).unwrap()
    };
    // the engine (and hence the summation order) depends on the thread
    // count, so compare backends at a fixed `threads` each time
    for threads in [2usize, 4] {
        let serial = solve(Backend::Serial, threads);
        for backend in [Backend::Scoped, Backend::Pool] {
            let other = solve(backend, threads);
            assert_eq!(serial.iterations, other.iterations, "{backend:?}/t{threads}");
            assert_eq!(serial.x, other.x, "{backend:?}/t{threads}: solutions diverge");
        }
    }
}

#[test]
fn ssor_precond_is_bit_identical_across_backends() {
    // the serial and pool backends run the compiled distance-1 program
    // forward then exactly mirrored (StepProgram::reversed); the scoped
    // backend recurses the tree both ways — all three must agree bitwise
    let a = gen::stencil2d_5pt(14, 14);
    let n = a.nrows();
    let r: Vec<f64> = (0..n).map(|i| ((i * 11 + 5) % 17) as f64 * 0.3 - 2.0).collect();
    for threads in [2usize, 4] {
        let mut outs = Vec::new();
        for backend in [Backend::Serial, Backend::Scoped, Backend::Pool] {
            let op =
                Operator::build(&a, OpConfig::new().threads(threads).backend(backend)).unwrap();
            let mut z = vec![0.0; n];
            op.ssor_precond(&r, &mut z).unwrap();
            assert!(z.iter().any(|&v| v != 0.0), "{backend:?}: sweep produced nothing");
            outs.push(z);
        }
        assert_eq!(outs[0], outs[1], "serial vs scoped, t{threads}");
        assert_eq!(outs[0], outs[2], "serial vs pool, t{threads}");
    }
}

#[test]
fn serve_solve_round_trip_matches_direct_solve() {
    // the serve endpoint and the facade must agree (same operator
    // config); request/response fields per docs/SERVE_PROTOCOL.md
    use race::serve::{MatvecService, ServeOptions};
    let opts = ServeOptions {
        matrices: vec!["stencil2d:10x10".to_string()],
        threads: 2,
        small: true,
        ..Default::default()
    };
    let svc = MatvecService::build(&opts).unwrap();
    let n = svc.entries()[0].n;
    let (_, a) = race::coordinator::resolve_matrix("stencil2d:10x10", true).unwrap();
    let rhs = rhs_for(&a);
    assert_eq!(rhs.len(), n);
    let served = svc.solve(None, &rhs, &SolveConfig::new().tol(1e-9)).unwrap();
    assert!(served.converged);
    assert!(true_rel_residual(&a, &rhs, &served.x) <= 1e-8);
    let op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
    let direct = op.solve(&rhs, &SolveConfig::new().tol(1e-9)).unwrap();
    // identical operator pipeline + identical arithmetic -> identical
    // iteration count; solutions agree to solver accuracy
    assert_eq!(served.iterations, direct.iterations);
    let scale = direct.x.iter().fold(0f64, |m, v| m.max(v.abs()));
    for i in 0..n {
        assert!((served.x[i] - direct.x[i]).abs() <= 1e-9 * (1.0 + scale), "row {i}");
    }
}
