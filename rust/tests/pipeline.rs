//! Integration tests over the coordinator pipeline, the corpus and the
//! figure-level claims at test scale. (The matvec service tests live in
//! `rust/tests/serve.rs` since the service became the `serve` subsystem.)

use race::cachesim;
use race::color::{abmc_schedule, mc_schedule};
use race::coordinator::{self, Method};
use race::gen;
use race::machine;
use race::race::{RaceConfig, RaceEngine};
use race::sim;

/// Every corpus matrix runs the full RACE pipeline correctly (small scale).
#[test]
fn corpus_race_pipeline_correct() {
    let m = machine::skx();
    for e in gen::corpus() {
        let r = coordinator::run_pipeline(e.name, Method::Race, 4, &m, true)
            .unwrap_or_else(|err| panic!("{}: {err}", e.name));
        assert!(r.max_rel_err < 1e-9, "{}: err={}", e.name, r.max_rel_err);
        assert!(r.eta > 0.0 && r.eta <= 1.0, "{}: eta={}", e.name, r.eta);
        assert!(r.sim.gflops > 0.0, "{}", e.name);
    }
}

/// The paper's global headline at test scale: summed over the corpus,
/// RACE-simulated SymmSpMV beats the best coloring method clearly.
#[test]
fn race_beats_colorings_in_aggregate() {
    let m = machine::skx();
    let mut g_race_sum = 0.0;
    let mut g_best_color_sum = 0.0;
    for e in gen::corpus().into_iter().step_by(3) {
        let a0 = (e.build)(true);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let nnz = a.nnz();
        let t = m.cores;
        let cfg = RaceConfig { threads: t, ..Default::default() };
        let eng = RaceEngine::build(&a, &cfg).unwrap();
        let up = eng.permuted_matrix().upper_triangle();
        let tr = cachesim::measure_symmspmv_traffic(&up, nnz, &m);
        g_race_sum += sim::simulate_race(&m, &eng, &up, tr.bytes_total, nnz).gflops;

        let mc = mc_schedule(&a, 2);
        let a_mc = a.permute_symmetric(&mc.perm);
        let up_mc = a_mc.upper_triangle();
        let tr_mc = cachesim::measure_symmspmv_traffic(&up_mc, nnz, &m);
        let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;
        let ab = abmc_schedule(&a, (a.nrows() / 64).max(16), 2);
        let a_ab = a.permute_symmetric(&ab.perm);
        let up_ab = a_ab.upper_triangle();
        let tr_ab = cachesim::measure_symmspmv_traffic(&up_ab, nnz, &m);
        let g_ab = sim::simulate_color(&m, &ab, &up_ab, t, tr_ab.bytes_total, nnz).gflops;
        g_best_color_sum += g_mc.max(g_ab);
    }
    assert!(
        g_race_sum > 1.2 * g_best_color_sum,
        "aggregate RACE {g_race_sum:.2} vs best coloring {g_best_color_sum:.2}"
    );
}

/// CG through every executor converges to the same solution.
#[test]
fn cg_all_backends_same_solution() {
    use race::kernels::{self, cg_solve};
    let a0 = gen::stencil2d_5pt(24, 24);
    let n = a0.nrows();
    let rhs = vec![1.0; n];

    // serial in natural order
    let upper0 = a0.upper_triangle();
    let mut x_serial = vec![0.0; n];
    let r0 = cg_solve(
        &mut |v, out| kernels::symmspmv_serial(&upper0, v, out),
        &rhs,
        &mut x_serial,
        1e-10,
        4000,
    );
    assert!(r0.converged);

    // RACE (permuted: solve in permuted space, compare back)
    let cfg = RaceConfig { threads: 4, ..Default::default() };
    let eng = RaceEngine::build(&a0, &cfg).unwrap();
    let upper_r = eng.permuted_matrix().upper_triangle();
    let rhs_p = coordinator::permute_vec(&rhs, &eng.perm);
    let mut x_race_p = vec![0.0; n];
    let r1 = cg_solve(
        &mut |v, out| kernels::symmspmv_race(&eng, &upper_r, v, out),
        &rhs_p,
        &mut x_race_p,
        1e-10,
        4000,
    );
    assert!(r1.converged);
    for (old, &new) in eng.perm.iter().enumerate() {
        assert!(
            (x_serial[old] - x_race_p[new as usize]).abs() < 1e-6,
            "row {old}"
        );
    }
}

/// Figure-2 shape at test scale: MC SymmSpMV slower than SpMV on Spin.
#[test]
fn fig2_shape_mc_loses_to_spmv() {
    let m = machine::ivb();
    let e = gen::corpus_entry("Spin-26").unwrap();
    let a0 = (e.build)(true);
    let perm = race::graph::rcm(&a0);
    let a = a0.permute_symmetric(&perm);
    let nnz = a.nnz();
    let t = m.cores;
    let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);
    let g_spmv = sim::simulate_spmv(&m, &a, t, tr_spmv.bytes_total).gflops;
    let mc = mc_schedule(&a, 2);
    let a_mc = a.permute_symmetric(&mc.perm);
    let up_mc = a_mc.upper_triangle();
    let tr_mc = cachesim::measure_symmspmv_traffic(&up_mc, nnz, &m);
    let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;
    assert!(g_mc < g_spmv, "MC {g_mc} must lose to SpMV {g_spmv} (paper Fig. 2)");
}

/// Explain path (Figs. 4-14 walkthrough) produces a sane tree for the
/// paper's 16x16 stencil / 8 threads example.
#[test]
fn explain_walkthrough_numbers() {
    let a = gen::race_paper_stencil(16, 16);
    let cfg = RaceConfig { threads: 8, dist: 2, eps: vec![0.6, 0.5], ..Default::default() };
    let eng = RaceEngine::build(&a, &cfg).unwrap();
    // the paper's Fig. 13 finds ~8 stage-0 level groups and recursion on
    // the inner ones; η = 0.73 for their exact stencil. Ours is a similar
    // stencil: assert the same regime rather than the exact number.
    assert!(eng.nlevels0 >= 14 && eng.nlevels0 <= 40, "nlevels={}", eng.nlevels0);
    let eta = eng.efficiency();
    assert!(eta > 0.45 && eta <= 1.0, "eta={eta}");
    assert!(eng.node_count() > 4);
}
