//! Chaos suite: deterministic fault injection across the execution tiers
//! (`race::fault`, sites catalogued in `docs/RELIABILITY.md`).
//!
//! Every test asserts the same resilience contract: an injected fault
//! never hangs or aborts the process — it surfaces as a structured error
//! (or is absorbed by a degradation rung) — and once the fault clears,
//! the very next call answers **bitwise identical** to a fault-free run.
//!
//! The injector is process-global, so tests that arm it serialize on one
//! mutex and disarm in a drop guard (a failing test cannot leak faults
//! into its neighbours). The CI `chaos-smoke` job additionally runs this
//! binary under seeded `RACE_FAULT` environment specs — the env-driven
//! smoke test at the bottom picks those up.

use race::fault;
use race::gen;
use race::op::{Backend, OpConfig, Operator};
use race::pool::WorkerPool;
use race::serve::{MatvecService, ServeOptions, Server};
use race::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Arms a fault spec for the guard's lifetime; holds the suite-wide
/// injection lock and disarms on drop (see `race::fault` module docs).
struct Armed(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Armed {
    fn install(spec: &str) -> Armed {
        static SERIAL: Mutex<()> = Mutex::new(());
        let g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
        fault::install_spec(spec).unwrap();
        Armed(g)
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn serve_opts(specs: &[&str]) -> ServeOptions {
    ServeOptions {
        matrices: specs.iter().map(|s| s.to_string()).collect(),
        threads: 2,
        addr: "127.0.0.1:0".to_string(),
        small: true,
        ..Default::default()
    }
}

/// A `pool.step` panic inside a worker surfaces as `Err(ExecError)` on
/// the flat pool backend — never as a caller panic or a hang — and the
/// pool recovers: the next sweep is bitwise identical to the fault-free
/// answer.
#[test]
fn pool_step_panic_surfaces_structured_error_then_recovers() {
    let a = gen::stencil2d_5pt(20, 20);
    let n = a.nrows();
    let op = Operator::build(&a, OpConfig::new().threads(3).backend(Backend::Pool)).unwrap();
    let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.25 - 1.5).collect();
    let mut want = vec![0.0; n];
    op.symmspmv(&x, &mut want).unwrap();
    {
        let _g = Armed::install("pool.step=panic#1");
        let mut b = vec![0.0; n];
        let err = op.symmspmv(&x, &mut b).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("injected fault at pool.step"), "{msg}");
        assert_eq!(fault::fired_at("pool.step"), 1);
    }
    // fault cleared: the pool drained its barriers, the next sweep is
    // bitwise equal to the pre-fault answer
    let mut b = vec![0.0; n];
    op.symmspmv(&x, &mut b).unwrap();
    assert_eq!(b, want, "post-fault sweep must be bitwise identical");
}

/// A worker told to retire between jobs (`pool.worker.exit`) is detected
/// and respawned at a later publish; the restart is counted and the pool
/// keeps reaching every participant.
#[test]
fn retired_worker_is_respawned_and_counted() {
    let _g = Armed::install("pool.worker.exit=exit#1");
    let pool = WorkerPool::new(3);
    pool.try_run(|_| {}).unwrap(); // one worker retires after this job
    let deadline = Instant::now() + Duration::from_secs(10);
    while pool.restarts() == 0 {
        assert!(Instant::now() < deadline, "respawn never observed");
        // each publish heals dead workers before handing out the job
        pool.try_run(|_| {}).unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(pool.restarts() >= 1);
    let hits = AtomicUsize::new(0);
    pool.try_run(|_| {
        hits.fetch_add(1, Ordering::SeqCst);
    })
    .unwrap();
    assert_eq!(hits.load(Ordering::SeqCst), 3, "healed pool reaches all participants");
}

/// Sharded degradation ladder: a failed dispatch on one domain walks to a
/// survivor (bit-identical answer); with every dispatch failing, the flat
/// pool rung serves — still bit-identical, never an error to the caller.
#[test]
fn sharded_dispatch_faults_degrade_bitwise() {
    let a = gen::stencil2d_5pt(16, 16);
    let n = a.nrows();
    let op = Operator::build(
        &a,
        OpConfig::new().threads(2).backend(Backend::Sharded { shards: 2 }).cache_bytes(8 << 10),
    )
    .unwrap();
    let x: Vec<f64> = (0..n).map(|i| ((i * 11 + 1) % 17) as f64 * 0.2 - 1.0).collect();
    let mut want = vec![0.0; n];
    op.symmspmv(&x, &mut want).unwrap();
    {
        // one shard's dispatch fails: the ladder walks to the survivor
        let _g = Armed::install("shard.dispatch=error#1");
        let mut b = vec![0.0; n];
        op.symmspmv(&x, &mut b).unwrap();
        assert_eq!(b, want, "survivor shard must answer bitwise identically");
        assert_eq!(fault::fired_at("shard.dispatch"), 1);
    }
    {
        // every dispatch fails (the first block's victim is still marked
        // failed, so only the survivor is even tried): the flat-pool
        // rung absorbs it
        let _g = Armed::install("shard.dispatch=error");
        let mut b = vec![0.0; n];
        op.symmspmv(&x, &mut b).unwrap();
        assert_eq!(b, want, "flat-pool rung must answer bitwise identically");
        assert!(fault::fired_at("shard.dispatch") >= 1, "the survivor was tried");
    }
    // ladders left failed-marks behind; a fresh call still answers
    let mut b = vec![0.0; n];
    op.symmspmv(&x, &mut b).unwrap();
    assert_eq!(b, want);
}

/// Serve tier over real TCP: a short write drops only that connection, a
/// handler panic answers a structured `internal` envelope, and the
/// service keeps answering correctly afterwards.
#[test]
fn tcp_write_and_handler_faults_are_isolated() {
    let server = Server::bind(&serve_opts(&["stencil2d:6x6"])).unwrap();
    let addr = server.local_addr();
    let svc = server.service().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let n = svc.entries()[0].n;
    let ones = vec![1.0; n];

    {
        // short write: the client sees a truncated line and EOF; the
        // server thread survives
        let _g = Armed::install("serve.write=short#1");
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.ends_with('\n'), "short write must truncate: {line:?}");
        assert!(Json::parse(line.trim()).is_err(), "half a response must not parse");
    }
    {
        // handler panic: caught at the protocol boundary, answered as a
        // structured internal error on the same connection
        let _g = Armed::install("serve.handle=panic#1");
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("request handler panicked"), "{line}");
        assert!(line.contains("\"internal\""), "{line}");
        // same connection, fault exhausted: served correctly
        writer.write_all(format!("{{\"x\": {ones:?}}}\n").as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        let b = j.get("b").and_then(|v| v.as_f64_arr()).unwrap();
        assert!(b.iter().all(|v| (v - 1.0).abs() < 1e-9), "{line}");
    }

    // faults cleared: health is green and shutdown drains cleanly
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"health\": true}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("health").and_then(|h| h.get("ok")), Some(&Json::Bool(true)), "{line}");
    writer.write_all(b"{\"shutdown\": true}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("shutting_down"), "{line}");
    handle.join().unwrap();
}

/// The byte-identity contract: with no faults armed and none of the new
/// flags set, the metrics exposition carries none of the resilience
/// counters and the stats error map has no extension codes — the wire
/// surfaces are exactly the pre-resilience ones.
#[test]
fn faultfree_expositions_carry_no_resilience_lines() {
    let _g = Armed::install(""); // explicitly disarm (CI may set RACE_FAULT)
    let svc = MatvecService::build(&serve_opts(&["stencil2d:6x6"])).unwrap();
    let n = svc.entries()[0].n;
    let (resp, _) = svc.handle(&format!("{{\"x\": {:?}}}", vec![1.0; n]));
    assert!(resp.contains("\"b\""), "{resp}");
    let text = match Json::parse(&svc.handle("{\"metrics\": true}").0).unwrap().get("metrics") {
        Some(Json::Str(t)) => t.clone(),
        other => panic!("expected metrics text, got {other:?}"),
    };
    assert!(!text.contains("race_shed_total"), "{text}");
    assert!(!text.contains("race_deadline_exceeded_total"), "{text}");
    assert!(!text.contains("race_worker_restarts_total"), "{text}");
    assert!(!text.contains("overloaded"), "{text}");
    let stats = svc.handle("{\"stats\": true}").0;
    assert!(!stats.contains("overloaded"), "{stats}");
    assert!(!stats.contains("deadline_exceeded"), "{stats}");
}

/// Env-driven smoke for the CI `chaos-smoke` job: re-arm whatever
/// `RACE_FAULT` spec the environment carries and drive a mixed workload
/// through it. The contract is weak by design — every call either
/// succeeds **bitwise identical** to the fault-free reference or returns
/// a structured error, and nothing hangs (the CI watchdog enforces the
/// wall clock). A no-op without `RACE_FAULT`.
#[test]
fn env_spec_smoke_no_hang_and_structured_errors_only() {
    let spec = std::env::var("RACE_FAULT").unwrap_or_default();
    if spec.trim().is_empty() {
        return;
    }
    // build everything fault-free first, so injection only exercises the
    // request paths (build-time sites like shard.clone are covered by
    // the dedicated tests above)
    let a = gen::stencil2d_5pt(16, 16);
    let n = a.nrows();
    let flat = Operator::build(&a, OpConfig::new().threads(2).backend(Backend::Pool)).unwrap();
    let sharded = Operator::build(
        &a,
        OpConfig::new().threads(2).backend(Backend::Sharded { shards: 2 }).cache_bytes(8 << 10),
    )
    .unwrap();
    let svc = MatvecService::build(&serve_opts(&["stencil2d:6x6"])).unwrap();
    let sn = svc.entries()[0].n;
    let x: Vec<f64> = (0..n).map(|i| ((i * 5 + 2) % 11) as f64 * 0.3 - 1.2).collect();
    let mut want = vec![0.0; n];
    flat.symmspmv(&x, &mut want).unwrap();

    let _g = Armed::install(&spec);
    for round in 0..32 {
        let mut b = vec![0.0; n];
        match flat.symmspmv(&x, &mut b) {
            Ok(()) => assert_eq!(b, want, "round {round}: flat result drifted"),
            Err(e) => assert!(!e.to_string().is_empty(), "round {round}: empty error"),
        }
        let mut b = vec![0.0; n];
        match sharded.symmspmv(&x, &mut b) {
            Ok(()) => assert_eq!(b, want, "round {round}: sharded result drifted"),
            Err(e) => assert!(!e.to_string().is_empty(), "round {round}: empty error"),
        }
        // the protocol boundary always answers one JSON line — success,
        // a structured error envelope, or the caught-panic envelope
        let (resp, stop) = svc.handle(&format!("{{\"x\": {:?}}}", vec![1.0; sn]));
        assert!(!stop);
        assert!(
            resp.contains("\"b\"") || resp.contains("\"error\""),
            "round {round}: unstructured response {resp}"
        );
    }
}
