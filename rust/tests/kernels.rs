//! Differential kernel conformance harness: the SIMD + prefetch tier
//! (`race::kernels::simd`, `simd` cargo feature) must produce **f64
//! bit-identical** results to the scalar reference tier at every kernel
//! entry point, across storage (CSR / `CsrPack` f64+f32) × range splits ×
//! multi-RHS widths (stack and heap scratch, lane remainders) × generator
//! families — plus targeted remainder-lane, empty-row, all-escape-row and
//! n∈{0,1} constructions, and end-to-end through the `Operator` facade
//! for backends × threads (whatever tier the build dispatches must match
//! the scalar kernel bitwise).
//!
//! The `simd` module is always compiled — the feature only flips the
//! dispatch inside the public entry points — so this harness pins the
//! scalar ≡ simd equivalence in *both* builds; CI runs it both ways.

mod common;

use common::{assert_bitwise, assert_close, pack_families, spd_families, test_vector};
use race::gen;
use race::kernels::{self, simd};
use race::op::{Backend, OpConfig, Operator, Storage};
use race::serve::{MatvecService, ServeOptions};
use race::sparse::{Coo, Csr, CsrPack, ValPrec};

/// RHS widths covering the SIMD span remainders (1..3), an odd middle, and
/// both sides of the kernels' 32-slot stack/heap scratch boundary.
const NRHS: [usize; 7] = [1, 2, 3, 5, 31, 32, 33];

/// Row-major multi-RHS input distinct per (row, rhs).
fn multi_vector(n: usize, nrhs: usize) -> Vec<f64> {
    let mut xs = vec![0f64; n * nrhs];
    for row in 0..n {
        for j in 0..nrhs {
            xs[row * nrhs + j] = ((row * (j + 2) + 3 * j + 7) % 13) as f64 * 0.3 - 1.6;
        }
    }
    xs
}

/// The escape-heavy corpus: u16 deltas cannot reach the far couplings, so
/// the packs route them through the side table. Row 0 of the upper
/// triangle is **all-escape** (its only off-diagonal partners are far),
/// and rows 5/9 add mid-matrix escapes so ranges starting past row 0 must
/// seed the escape cursor.
fn escape_matrix() -> Csr {
    let n = 70_000usize;
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 2.0 + (i % 7) as f64 * 0.25);
    }
    for (r, c, v) in [
        (0usize, 66_000usize, -1.0),
        (0, 67_500, 0.75),
        (0, 69_000, -0.5),
        (5, 67_000, 0.5),
        (9, 68_000, -0.25),
    ] {
        coo.push_sym(r, c, v);
    }
    coo.to_csr()
}

/// Rows with nnz ∈ 1..=10 in the upper triangle: covers nnz < lane width
/// (4), every `nnz % UNROLL` residue, and the prefetch-distance guard on
/// short rows.
fn remainder_matrix() -> Csr {
    let n = 64usize;
    let mut coo = Coo::new(n);
    for i in 0..n {
        coo.push(i, i, 3.0 + (i % 5) as f64 * 0.5);
    }
    for i in 0..n {
        let extra = i % 10; // upper-row nnz = 1 + extra (diag + neighbors)
        for k in 1..=extra {
            if i + k < n {
                coo.push_sym(i, i + k, ((i * 3 + k) % 7) as f64 * 0.3 - 0.9);
            }
        }
    }
    coo.to_csr()
}

/// Range splits exercised for every range kernel: the full sweep, an
/// uneven split sharing one output (scatter accumulation across the cut),
/// and a tail-only range (escape-cursor seeding on packs).
fn splits(n: usize) -> Vec<(usize, usize)> {
    if n < 8 {
        return vec![(0, n)];
    }
    vec![(0, n), (0, n / 3), (n / 3, n), (5, n)]
}

// =====================================================================
// CSR SymmSpMV: single and multi
// =====================================================================

#[test]
fn csr_symmspmv_simd_bitwise_equals_scalar_on_all_families() {
    for (name, a) in pack_families() {
        let n = a.nrows();
        let upper = a.upper_triangle();
        let x = test_vector(n);
        // tolerance anchor: the reference SpMV on the full matrix
        let want_ref = a.spmv_ref(&x);
        let mut full = vec![0.0; n];
        kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut full, 0, n);
        assert_close(&want_ref, &full, 1e-9, name);
        for (s, e) in splits(n) {
            let mut bs = vec![0.0; n];
            kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut bs, s, e);
            let mut bv = vec![0.0; n];
            simd::symmspmv_range_simd(&upper, &x, &mut bv, s, e);
            assert_bitwise(&bs, &bv, &format!("{name}: symmspmv [{s},{e})"));
        }
        // split ranges accumulating into one shared output
        let mut shared_s = vec![0.0; n];
        kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut shared_s, 0, n / 2);
        kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut shared_s, n / 2, n);
        let mut shared_v = vec![0.0; n];
        simd::symmspmv_range_simd(&upper, &x, &mut shared_v, 0, n / 2);
        simd::symmspmv_range_simd(&upper, &x, &mut shared_v, n / 2, n);
        assert_bitwise(&shared_s, &shared_v, &format!("{name}: shared-b split"));
    }
}

#[test]
fn csr_symmspmv_multi_simd_bitwise_across_rhs_widths() {
    for (name, a) in
        [("stencil9", gen::stencil2d_9pt(12, 11)), ("graphene", gen::graphene(8, 8))]
    {
        let n = a.nrows();
        let upper = a.upper_triangle();
        for nrhs in NRHS {
            let xs = multi_vector(n, nrhs);
            for (s, e) in splits(n) {
                let mut bs = vec![0f64; n * nrhs];
                kernels::symmspmv_range_multi_scalar(&upper, &xs, &mut bs, nrhs, s, e);
                let mut bv = vec![0f64; n * nrhs];
                simd::symmspmv_range_multi_simd(&upper, &xs, &mut bv, nrhs, s, e);
                assert_bitwise(&bs, &bv, &format!("{name}: multi nrhs={nrhs} [{s},{e})"));
            }
        }
    }
}

// =====================================================================
// Packed SymmSpMV: single and multi, f64 and f32, escapes
// =====================================================================

#[test]
fn pack_symmspmv_simd_bitwise_equals_scalar_on_all_families() {
    for (name, a) in pack_families() {
        let n = a.nrows();
        let upper = a.upper_triangle();
        let x = test_vector(n);
        for prec in [ValPrec::F64, ValPrec::F32] {
            // both tiers widen f32 identically, so even the f32 pack must
            // agree bitwise between scalar and simd
            let p = CsrPack::pack_upper(&upper, prec);
            for (s, e) in splits(n) {
                let mut bs = vec![0.0; n];
                kernels::symmspmv_range_pack_unchecked_scalar(&p, &x, &mut bs, s, e);
                let mut bv = vec![0.0; n];
                simd::symmspmv_range_pack_simd(&p, &x, &mut bv, s, e);
                assert_bitwise(&bs, &bv, &format!("{name}/{prec:?}: pack [{s},{e})"));
            }
        }
    }
}

#[test]
fn pack_symmspmv_simd_handles_escapes_and_all_escape_rows() {
    let a = escape_matrix();
    let n = a.nrows();
    let upper = a.upper_triangle();
    let p = CsrPack::pack_upper(&upper, ValPrec::F64);
    assert!(p.escapes() >= 5, "construction must force the side table");
    let x: Vec<f64> = (0..n).map(|i| ((i % 23) as f64) * 0.1 - 1.0).collect();
    for (s, e) in [(0, n), (4, n), (10, n), (0, 7)] {
        let mut bs = vec![0.0; n];
        kernels::symmspmv_range_pack_unchecked_scalar(&p, &x, &mut bs, s, e);
        let mut bv = vec![0.0; n];
        simd::symmspmv_range_pack_simd(&p, &x, &mut bv, s, e);
        assert_bitwise(&bs, &bv, &format!("escape pack [{s},{e})"));
    }
    // multi-RHS over the same escapes (span path + cursor)
    for nrhs in [1usize, 3, 33] {
        let xs = multi_vector(n, nrhs);
        let mut bs = vec![0f64; n * nrhs];
        kernels::symmspmv_range_multi_pack_scalar(&p, &xs, &mut bs, nrhs, 0, n);
        let mut bv = vec![0f64; n * nrhs];
        simd::symmspmv_range_multi_pack_simd(&p, &xs, &mut bv, nrhs, 0, n);
        assert_bitwise(&bs, &bv, &format!("escape pack multi nrhs={nrhs}"));
    }
}

#[test]
fn pack_symmspmv_multi_simd_bitwise_across_rhs_widths() {
    let a = gen::stencil2d_9pt(12, 11);
    let n = a.nrows();
    let upper = a.upper_triangle();
    let p = CsrPack::pack_upper(&upper, ValPrec::F64);
    for nrhs in NRHS {
        let xs = multi_vector(n, nrhs);
        let mut bs = vec![0f64; n * nrhs];
        kernels::symmspmv_range_multi_pack_scalar(&p, &xs, &mut bs, nrhs, 0, n);
        let mut bv = vec![0f64; n * nrhs];
        simd::symmspmv_range_multi_pack_simd(&p, &xs, &mut bv, nrhs, 0, n);
        assert_bitwise(&bs, &bv, &format!("pack multi nrhs={nrhs}"));
    }
}

// =====================================================================
// Affine SpMV (MPK work unit): CSR and pack, single and multi
// =====================================================================

#[test]
fn affine_simd_bitwise_equals_scalar_on_all_families() {
    for (name, a) in pack_families() {
        let n = a.nrows();
        let src: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let accv: Vec<f64> = (0..n).map(|i| (i as f64 * 0.19).cos()).collect();
        for (sigma, tau, rho, acc) in
            [(1.0, 0.0, 0.0, None), (0.4, -0.2, -1.0, Some(accv.as_slice()))]
        {
            for (s, e) in splits(n) {
                let mut ds = vec![0.0; n];
                kernels::spmv_range_affine_scalar(&a, &src, acc, &mut ds, sigma, tau, rho, s, e);
                let mut dv = vec![0.0; n];
                simd::spmv_range_affine_simd(&a, &src, acc, &mut dv, sigma, tau, rho, s, e);
                assert_bitwise(&ds, &dv, &format!("{name}: affine σ={sigma} [{s},{e})"));
            }
            // Full-kind packs, both precisions
            for prec in [ValPrec::F64, ValPrec::F32] {
                let p = CsrPack::pack_full(&a, prec);
                let mut ds = vec![0.0; n];
                kernels::spmv_range_affine_pack_scalar(&p, &src, acc, &mut ds, sigma, tau, rho, 0, n);
                let mut dv = vec![0.0; n];
                simd::spmv_range_affine_pack_simd(&p, &src, acc, &mut dv, sigma, tau, rho, 0, n);
                assert_bitwise(&ds, &dv, &format!("{name}/{prec:?}: affine pack σ={sigma}"));
            }
        }
    }
}

#[test]
fn affine_multi_simd_bitwise_across_rhs_widths() {
    let a = gen::graphene(7, 7);
    let n = a.nrows();
    let p = CsrPack::pack_full(&a, ValPrec::F64);
    for nrhs in NRHS {
        let srcs = multi_vector(n, nrhs);
        let accv = multi_vector(n, nrhs).iter().map(|v| v * 0.5 - 0.1).collect::<Vec<_>>();
        for (sigma, tau, rho, acc) in
            [(1.0, 0.0, 0.0, None), (0.4, -0.2, -1.0, Some(accv.as_slice()))]
        {
            let mut ds = vec![0f64; n * nrhs];
            kernels::spmv_range_affine_multi_scalar(
                &a, &srcs, acc, &mut ds, nrhs, sigma, tau, rho, 0, n,
            );
            let mut dv = vec![0f64; n * nrhs];
            simd::spmv_range_affine_multi_simd(
                &a, &srcs, acc, &mut dv, nrhs, sigma, tau, rho, 0, n,
            );
            assert_bitwise(&ds, &dv, &format!("affine multi nrhs={nrhs} σ={sigma}"));
            let mut dps = vec![0f64; n * nrhs];
            kernels::spmv_range_affine_multi_pack_scalar(
                &p, &srcs, acc, &mut dps, nrhs, sigma, tau, rho, 0, n,
            );
            let mut dpv = vec![0f64; n * nrhs];
            simd::spmv_range_affine_multi_pack_simd(
                &p, &srcs, acc, &mut dpv, nrhs, sigma, tau, rho, 0, n,
            );
            assert_bitwise(&dps, &dpv, &format!("affine multi pack nrhs={nrhs} σ={sigma}"));
        }
    }
}

#[test]
fn affine_simd_handles_full_pack_escapes() {
    let a = escape_matrix();
    let n = a.nrows();
    let p = CsrPack::pack_full(&a, ValPrec::F64);
    assert!(p.escapes() >= 10, "symmetric far couplings escape in both triangles");
    let src: Vec<f64> = (0..n).map(|i| ((i % 17) as f64) * 0.2 - 1.3).collect();
    for (s, e) in [(0, n), (4, n), (0, 12)] {
        let mut ds = vec![0.0; n];
        kernels::spmv_range_affine_pack_scalar(&p, &src, None, &mut ds, 1.0, 0.0, 0.0, s, e);
        let mut dv = vec![0.0; n];
        simd::spmv_range_affine_pack_simd(&p, &src, None, &mut dv, 1.0, 0.0, 0.0, s, e);
        assert_bitwise(&ds, &dv, &format!("escape affine pack [{s},{e})"));
    }
}

// =====================================================================
// Distance-1 Gauss–Seidel row update
// =====================================================================

#[test]
fn gs_sweeps_simd_bitwise_equal_scalar() {
    for (name, a) in spd_families() {
        let n = a.nrows();
        let b = common::rhs_for(&a);
        let x0 = test_vector(n);
        let mut xs = x0.clone();
        let mut xv = x0;
        // three forward sweeps magnify any divergence in the row update
        for _ in 0..3 {
            for row in 0..n {
                kernels::gs_row_scalar(&a, &b, &mut xs, row);
            }
            for row in 0..n {
                simd::gs_row_simd(&a, &b, &mut xv, row);
            }
        }
        assert_bitwise(&xs, &xv, &format!("{name}: GS sweeps"));
    }
}

// =====================================================================
// Edge cases: remainder lanes, empty rows, n = 0 / n = 1
// =====================================================================

#[test]
fn remainder_lane_rows_bitwise_equal() {
    let a = remainder_matrix();
    let n = a.nrows();
    let upper = a.upper_triangle();
    let x = test_vector(n);
    let want_ref = a.spmv_ref(&x);
    let mut bs = vec![0.0; n];
    kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut bs, 0, n);
    assert_close(&want_ref, &bs, 1e-9, "remainder: scalar vs ref");
    let mut bv = vec![0.0; n];
    simd::symmspmv_range_simd(&upper, &x, &mut bv, 0, n);
    assert_bitwise(&bs, &bv, "remainder: symmspmv");
    let p = CsrPack::pack_upper(&upper, ValPrec::F64);
    let mut bp = vec![0.0; n];
    simd::symmspmv_range_pack_simd(&p, &x, &mut bp, 0, n);
    assert_bitwise(&bs, &bp, "remainder: pack symmspmv");
    // the affine kernel sees every row length too (full matrix)
    let mut ds = vec![0.0; n];
    kernels::spmv_range_affine_scalar(&a, &x, None, &mut ds, 1.0, 0.0, 0.0, 0, n);
    let mut dv = vec![0.0; n];
    simd::spmv_range_affine_simd(&a, &x, None, &mut dv, 1.0, 0.0, 0.0, 0, n);
    assert_bitwise(&ds, &dv, "remainder: affine");
}

#[test]
fn empty_rows_and_tiny_matrices() {
    // empty rows are legal for the pure-gather affine kernel
    let mut coo = Coo::new(8);
    for (r, c, v) in [(1usize, 1usize, 2.0), (3, 4, -1.0), (4, 3, -1.0), (6, 6, 1.5)] {
        coo.push(r, c, v);
    }
    let a = coo.to_csr();
    let src = test_vector(8);
    for (sigma, tau) in [(1.0, 0.0), (0.7, -0.3)] {
        let mut ds = vec![0.0; 8];
        kernels::spmv_range_affine_scalar(&a, &src, None, &mut ds, sigma, tau, 0.0, 0, 8);
        let mut dv = vec![0.0; 8];
        simd::spmv_range_affine_simd(&a, &src, None, &mut dv, sigma, tau, 0.0, 0, 8);
        assert_bitwise(&ds, &dv, "empty rows: affine");
    }

    // n = 0: every CSR kernel must be a no-op, not a panic
    let e = Coo::new(0).to_csr();
    let eu = e.upper_triangle();
    let (mut b0, x0): (Vec<f64>, Vec<f64>) = (vec![], vec![]);
    simd::symmspmv_range_simd(&eu, &x0, &mut b0, 0, 0);
    kernels::symmspmv_range_unchecked_scalar(&eu, &x0, &mut b0, 0, 0);
    let mut d0: Vec<f64> = vec![];
    simd::spmv_range_affine_simd(&e, &x0, None, &mut d0, 1.0, 0.0, 0.0, 0, 0);
    simd::symmspmv_range_multi_simd(&eu, &x0, &mut b0, 1, 0, 0);

    // n = 1: the split-diagonal head is the whole row
    let mut one = Coo::new(1);
    one.push(0, 0, 2.5);
    let a1 = one.to_csr();
    let u1 = a1.upper_triangle();
    let x1 = vec![1.25];
    let mut bs1 = vec![0.0];
    kernels::symmspmv_range_unchecked_scalar(&u1, &x1, &mut bs1, 0, 1);
    let mut bv1 = vec![0.0];
    simd::symmspmv_range_simd(&u1, &x1, &mut bv1, 0, 1);
    assert_bitwise(&bs1, &bv1, "n=1 symmspmv");
    let p1 = CsrPack::pack_upper(&u1, ValPrec::F64);
    let mut bp1 = vec![0.0];
    simd::symmspmv_range_pack_simd(&p1, &x1, &mut bp1, 0, 1);
    assert_bitwise(&bs1, &bp1, "n=1 pack symmspmv");
}

// =====================================================================
// End-to-end: whatever tier the build dispatches, the Operator facade
// must match the scalar kernel bitwise — backends × threads × storage.
// =====================================================================

#[test]
fn facade_backends_match_scalar_reference_bitwise() {
    for (name, a) in common::families() {
        for threads in common::THREADS {
            for &backend in &common::BACKENDS {
                for storage in [Storage::Csr, Storage::Pack] {
                    let cfg = OpConfig::new()
                        .threads(threads)
                        .backend(backend)
                        .storage(storage)
                        .cache_bytes(8 << 10);
                    let op = Operator::build(&a, cfg).unwrap();
                    let n = op.n();
                    let xp = test_vector(n);
                    // scalar reference on the operator's own permuted
                    // matrix — tier-independent by construction
                    let upper = op.permuted_matrix().upper_triangle();
                    let mut want = vec![0.0; n];
                    kernels::symmspmv_range_unchecked_scalar(&upper, &xp, &mut want, 0, n);
                    let mut got = vec![0.0; n];
                    op.symmspmv_permuted(&xp, &mut got).unwrap();
                    assert_bitwise(
                        &want,
                        &got,
                        &format!("{name}/t{threads}/{backend:?}/{storage:?}"),
                    );
                }
            }
        }
    }
    // the sharded tier composes the same kernels — one family suffices
    let a = gen::stencil2d_5pt(16, 13);
    let op = Operator::build(
        &a,
        OpConfig::new().threads(2).backend(Backend::Sharded { shards: 2 }).cache_bytes(8 << 10),
    )
    .unwrap();
    let n = op.n();
    let xp = test_vector(n);
    let upper = op.permuted_matrix().upper_triangle();
    let mut want = vec![0.0; n];
    kernels::symmspmv_range_unchecked_scalar(&upper, &xp, &mut want, 0, n);
    let mut got = vec![0.0; n];
    op.symmspmv_permuted(&xp, &mut got).unwrap();
    assert_bitwise(&want, &got, "sharded facade");
}

// =====================================================================
// Tier reporting surfaces
// =====================================================================

#[test]
fn tier_reporting_is_consistent_and_feature_gated() {
    let tier = kernels::active_tier();
    if cfg!(feature = "simd") {
        assert_ne!(tier, kernels::KernelTier::Scalar, "simd builds never report scalar");
        assert_eq!(tier, kernels::detected_tier());
    } else {
        assert_eq!(tier, kernels::KernelTier::Scalar);
    }
    let a = gen::stencil2d_5pt(10, 10);
    let op = Operator::build(&a, OpConfig::new().threads(2)).unwrap();
    assert_eq!(op.kernel_tier(), tier);
}

#[test]
fn exec_report_carries_kernel_tier() {
    let a = gen::stencil2d_5pt(16, 13);
    let op =
        Operator::build(&a, OpConfig::new().threads(2).backend(Backend::Pool)).unwrap();
    race::obs::set_enabled(true);
    let x = test_vector(op.n());
    let mut b = vec![0.0; op.n()];
    op.symmspmv(&x, &mut b).unwrap();
    let report = op.worker_pool().take_exec_report();
    race::obs::set_enabled(false);
    let r = report.expect("obs-enabled pool run records a report");
    assert_eq!(r.kernel_tier, kernels::active_tier().as_str());
}

#[test]
fn serve_stats_kernel_tier_gated_by_feature() {
    let svc = MatvecService::build(&ServeOptions {
        matrices: vec!["spin:6".to_string()],
        threads: 2,
        addr: "127.0.0.1:0".to_string(),
        small: true,
        ..Default::default()
    })
    .unwrap();
    let s = svc.stats_json().to_string();
    if cfg!(feature = "simd") {
        assert!(s.contains("\"kernel_tier\""), "simd build stats must report the tier: {s}");
        assert!(s.contains(kernels::active_tier().as_str()));
    } else {
        assert!(
            !s.contains("kernel_tier"),
            "default build stats must keep their historical shape byte-identical: {s}"
        );
    }
}

/// Pins the satellite regression: the default build's `BENCH_perf.json`
/// must keep byte-identical kernel keys, so the bench's simd series has
/// to be emitted behind a `cfg!(feature = "simd")` gate in the source.
#[test]
fn bench_perf_simd_series_is_feature_gated_in_source() {
    let src =
        std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/benches/perf_kernel.rs"))
            .unwrap();
    assert!(
        src.contains("cfg!(feature = \"simd\")"),
        "perf_kernel must gate its simd series on the feature"
    );
    assert!(src.contains("\"simd\""), "perf_kernel must emit a `simd` kernel series");
    assert!(src.contains("speedup_simd"), "perf_kernel must emit the simd speedup key");
}
