//! Fig. 16: parallel efficiency η vs. thread count for ALL corpus
//! matrices with the paper's default parameters (ε₀=ε₁=0.8, ε_{s>1}=0.5).
//! The paper finds ≥80% efficiency for most matrices up to intermediate
//! thread counts, Graphene best and crankseg_1 worst.

use race::gen;
use race::race::{RaceConfig, RaceEngine};

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let threads = [1usize, 2, 5, 10, 20, 40, 80];
    print!("{:<26}", "matrix");
    for t in threads {
        print!(" {t:>7}");
    }
    println!();
    let mut best: (f64, &str) = (0.0, "");
    let mut worst: (f64, &str) = (2.0, "");
    for e in gen::corpus() {
        let a0 = (e.build)(small);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        print!("{:<26}", e.name);
        let mut eta20 = 1.0;
        for t in threads {
            let cfg = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            let eta = RaceEngine::build(&a, &cfg).map(|e| e.efficiency()).unwrap_or(0.0);
            if t == 20 {
                eta20 = eta;
            }
            print!(" {eta:>7.3}");
        }
        println!();
        if eta20 > best.0 {
            best = (eta20, e.name);
        }
        if eta20 < worst.0 {
            worst = (eta20, e.name);
        }
    }
    println!("\nat 20 threads: best = {} (eta={:.3}), worst = {} (eta={:.3})", best.1, best.0, worst.1, worst.0);
    println!("(paper: Graphene-4096 best, crankseg_1 worst)");
}
