//! §Perf harness: hot-path iteration log for the serial SymmSpMV kernel
//! (the unit of work every parallel executor schedules), its
//! delta-compressed pack twins, and the cache simulator (the corpus-level
//! bench bottleneck). Run with `cargo bench --bench perf_kernel`; results
//! recorded in EXPERIMENTS.md §Perf.
//!
//! Emits `BENCH_perf.json` (override the path with `RACE_BENCH_OUT`, same
//! shape family as `BENCH_mpk.json`) so the scalar / unrolled / packed
//! kernel GF/s trajectory is machine-readable from this PR onward:
//! `{"bench": "perf_kernel", "cases": [{matrix, kernel, gfs, median_ms}],
//! "phases": [{phase, ms, count}]}` — the `phases` breakdown comes from
//! the [`race::obs`] span recorder wrapped around the full
//! `Operator::symmspmv` service path (permute in → kernel → permute out).
//!
//! `RACE_BENCH_FULL=1` runs the larger variants.

use race::cachesim;
use race::gen;
use race::kernels;
use race::machine;
use race::op;
use race::sparse::{CsrPack, ValPrec};
use race::util::bench::{bench, report, BenchStats};
use race::util::json::Json;

fn main() {
    let full = std::env::var("RACE_BENCH_FULL").is_ok();
    // representative pair: high-N_nzr stencil + low-N_nzr quantum chain
    let mats = vec![
        (
            "stencil27",
            if full { gen::stencil3d_27pt(40, 40, 40) } else { gen::stencil3d_27pt(24, 24, 24) },
        ),
        ("spin", gen::spin_chain_xxz(if full { 17 } else { 14 }, gen::SpinKind::XXZ)),
    ];
    fn case_row(matrix: &str, kernel: &str, s: &BenchStats, flops: f64) -> Json {
        Json::obj(vec![
            ("matrix", Json::Str(matrix.to_string())),
            ("kernel", Json::Str(kernel.to_string())),
            ("gfs", Json::Num(s.gflops(flops))),
            ("median_ms", Json::Num(s.median * 1e3)),
        ])
    }
    let mut rows = Vec::new();
    for (name, a0) in &mats {
        let perm = race::graph::rcm(a0);
        let a = a0.permute_symmetric(&perm);
        let upper = op::upper(&a);
        let pack64 = CsrPack::pack_upper(&upper, ValPrec::F64);
        let pack32 = CsrPack::pack_upper(&upper, ValPrec::F32);
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut b = vec![0.0; n];
        let flops = 2.0 * a.nnz() as f64;
        println!("== {} ({} rows, {} nnz, N_nzr {:.1}) ==", name, n, a.nnz(), a.nnzr());

        let s = bench("checked (pre-perf baseline)", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_checked(&upper, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "checked", &s, flops));
        let s = bench("symmspmv_range (external entry)", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range(&upper, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "range", &s, flops));
        let s = bench("unchecked (no bounds checks)", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_unchecked(&upper, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "unchecked", &s, flops));
        let s = bench("unrolled x4", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_unrolled(&upper, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "unrolled", &s, flops));
        let s = bench("scalar reference", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_scalar(&upper, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "scalar", &s, flops));
        // scalar-vs-simd differential series. Emitted only on `simd`
        // builds so the default build's BENCH_perf.json keeps its kernel
        // keys byte-identical; the `speedup_simd` leaf lands in the
        // baseline differ's noisy higher-better tier (`speedup*`), so CI
        // diffs warn rather than gate. RACE_PERF_ASSERT=1 (perf hardware
        // only) hard-asserts the vector tier is not slower on the
        // regular high-N_nzr stencil.
        if cfg!(feature = "simd") {
            let s_sc = bench("simd-tier scalar twin", 0.4, || {
                b.iter_mut().for_each(|v| *v = 0.0);
                kernels::symmspmv_range_unchecked_scalar(&upper, &x, &mut b, 0, n);
            });
            report(&s_sc, Some(flops));
            let s_v = bench("simd + software prefetch", 0.4, || {
                b.iter_mut().for_each(|v| *v = 0.0);
                race::kernels::simd::symmspmv_range_simd(&upper, &x, &mut b, 0, n);
            });
            report(&s_v, Some(flops));
            let speedup = s_sc.median / s_v.median;
            println!(
                "  simd tier {}: {speedup:.2}x vs scalar twin",
                kernels::detected_tier().as_str()
            );
            let mut row = vec![
                ("matrix", Json::Str(name.to_string())),
                ("kernel", Json::Str("simd".to_string())),
                ("gfs", Json::Num(s_v.gflops(flops))),
                ("median_ms", Json::Num(s_v.median * 1e3)),
            ];
            row.push(("speedup_simd", Json::Num(speedup)));
            rows.push(Json::obj(row));
            if speedup < 1.0 {
                let msg = format!("simd slower than scalar on {name}: {speedup:.2}x");
                if std::env::var("RACE_PERF_ASSERT").is_ok() && *name == "stencil27" {
                    panic!("{msg}");
                }
                println!("  warning: {msg} (noisy-timing tier; not gated)");
            }
        }
        let s = bench("pack f64 (u16 deltas)", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_pack(&pack64, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "pack_f64", &s, flops));
        let s = bench("pack f32 (u16 deltas + f32 vals)", 0.4, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_pack(&pack32, &x, &mut b, 0, n);
        });
        report(&s, Some(flops));
        rows.push(case_row(name, "pack_f32", &s, flops));
        std::hint::black_box(&b);

        // roofline context for this matrix on the host
        let host = machine::host(32);
        let alpha = race::perfmodel::alpha_opt_symmspmv(a.nnzr());
        let w = race::perfmodel::symmspmv_window(&host, alpha, a.nnzr());
        println!(
            "host 1-core roofline window (optimal alpha): {:.2}..{:.2} GF/s\n",
            w.p_copy / 1e9,
            w.p_load / 1e9
        );
    }

    // cache simulator throughput (drives the corpus benches)
    println!("== cache simulator throughput ==");
    let a = &mats[0].1;
    let upper = op::upper(a);
    let m = machine::skx();
    let s = bench("measure_symmspmv_traffic", 0.5, || {
        std::hint::black_box(cachesim::measure_symmspmv_traffic(&upper, a.nnz(), &m));
    });
    report(&s, None);
    println!("  = {:.1} M accesses/s", 2.0 * upper.nnz() as f64 / s.median / 1e6);

    // facade path through the obs recorder: where one full
    // `Operator::symmspmv` service spends its time (permute in, pooled
    // kernel, permute out) — the recorder replaces the ad-hoc Instant
    // pairs this breakdown used to require
    println!("== operator facade phases (obs recorder) ==");
    let op = race::op::Operator::build(a, race::op::OpConfig::new().threads(4)).unwrap();
    let nf = op.n();
    let xf: Vec<f64> = (0..nf).map(|i| (i as f64 * 0.3).sin()).collect();
    let mut bf = vec![0.0; nf];
    op.symmspmv(&xf, &mut bf).unwrap(); // warm-up: pack encode + program compile
    race::obs::set_enabled(true);
    race::obs::recorder().drain();
    let flops_f = 2.0 * a.nnz() as f64;
    let s = bench("operator symmspmv (facade)", 0.4, || {
        op.symmspmv(&xf, &mut bf).unwrap();
    });
    race::obs::set_enabled(false);
    report(&s, Some(flops_f));
    rows.push(case_row(mats[0].0, "operator", &s, flops_f));
    let facade_events = race::obs::recorder().drain();
    let phase_rows: Vec<Json> = race::obs::phase_totals(&facade_events)
        .iter()
        .map(|p| {
            println!("  {:<20} {:>10.3} ms  x{}", p.name, p.total_ms(), p.count);
            Json::obj(vec![
                ("phase", Json::Str(p.name.to_string())),
                ("ms", Json::Num(p.total_ms())),
                ("count", Json::Num(p.count as f64)),
            ])
        })
        .collect();
    std::hint::black_box(&bf);

    let out = Json::obj(vec![
        ("bench", Json::Str("perf_kernel".to_string())),
        ("cases", Json::Arr(rows)),
        ("phases", Json::Arr(phase_rows)),
    ]);
    let path = race::obs::baseline::write_bench("BENCH_perf.json", out, Some(&m))
        .expect("write BENCH_perf.json");
    println!("wrote {path}");
}
