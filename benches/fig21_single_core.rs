//! Fig. 21: REAL single-core wallclock of SymmSpMV (with RACE ordering)
//! vs. SpMV across the corpus on the host — the one figure this testbed
//! can measure natively (it is a single-core figure in the paper too).
//! The paper's finding: for low-N_nzr matrices the short inner loop makes
//! SymmSpMV lose its storage advantage on a single core.

use race::gen;
use race::kernels;
use race::op;
use race::util::bench::bench;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    println!(
        "{:>3} {:<26} {:>8} {:>12} {:>12} {:>8}",
        "idx", "matrix", "N_nzr", "SymmSpMV", "SpMV", "ratio"
    );
    for e in gen::corpus() {
        let a0 = (e.build)(small);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let upper = op::upper(&a);
        let n = a.nrows();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut b = vec![0.0; n];
        let flops = 2.0 * a.nnz() as f64;

        let s_symm = bench(e.name, 0.15, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_serial(&upper, &x, &mut b);
        });
        let s_spmv = bench(e.name, 0.15, || {
            kernels::spmv(&a, &x, &mut b);
        });
        std::hint::black_box(&b);
        let g_symm = s_symm.gflops(flops);
        let g_spmv = s_spmv.gflops(flops);
        println!(
            "{:>3} {:<26} {:>8.2} {:>9.3}GF/s {:>9.3}GF/s {:>8.2}",
            e.index,
            e.name,
            a.nnzr(),
            g_symm,
            g_spmv,
            g_symm / g_spmv
        );
    }
    println!("\n(paper: ratio < 1 for low-N_nzr matrices like delaunay/Hubbard-12)");
}
