//! Shard-scaling: multi-RHS SymmSpMV throughput of `Backend::Sharded`
//! at 1 / 2 / 4 shards.
//!
//! Each shard is a CPU-affinity domain with its own pinned worker pool
//! and its own replica of the operator's triangle/pack storage; a
//! multi-RHS batch fans its columns out across the replicas. Before any
//! timing, every case is anchored bitwise against `Backend::Serial` —
//! placement is a performance hint, never a correctness input.
//!
//! On a single-domain host the headline is graceful degradation: the
//! logical-shard fallback must keep serving correct results at every
//! shard count, and the report shows what sharding costs or buys there.
//! On a real multi-socket machine the same bench reads as the paper's
//! scaling story (one replica per memory domain).
//!
//! Emits `BENCH_shard.json` (override with `RACE_BENCH_OUT`):
//! `{"bench": "shard_scaling", "matrix", "n", "nrhs",
//! "threads_per_shard", "cases": [{name, shards, median_s,
//! vectors_per_sec, speedup}]}`. `RACE_BENCH_FULL=1` runs a larger
//! matrix and longer timings.

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let (spec, threads, nrhs, secs) =
        if small { ("stencil2d:48x48", 2, 8, 0.05) } else { ("stencil2d:192x192", 4, 16, 0.2) };
    let doc = race::shard::bench_scaling(spec, true, &[1, 2, 4], threads, nrhs, secs)
        .expect("shard scaling bench");
    if let Some(race::util::json::Json::Arr(cases)) = doc.get("cases") {
        for c in cases {
            use race::util::json::Json;
            let get = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "shards {:.0}: {:.3} ms/batch = {:.0} vectors/s ({:.2}x vs 1 shard)",
                get("shards"),
                get("median_s") * 1e3,
                get("vectors_per_sec"),
                get("speedup")
            );
        }
    }
    let path = race::obs::baseline::write_bench("BENCH_shard.json", doc, None)
        .expect("write BENCH_shard.json");
    println!("wrote {path}");
}
