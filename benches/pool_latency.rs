//! Pool vs scoped-spawn executor latency, and batched serve throughput.
//!
//! Part 1 — the tentpole claim: for the same RACE schedule, the
//! persistent worker pool (`Backend::Pool` of the [`race::op::Operator`]
//! facade) answers a SymmSpMV no slower than the scoped-spawn executor
//! at every measured (matrix, threads) point: the per-call
//! `thread::scope` spawn/join rounds are replaced by one condvar wake
//! plus per-step barriers on resident workers. The scoped baseline runs
//! through the same handle's engine and upper triangle, so the
//! comparison isolates the execution runtime.
//!
//! Part 2 — serve batching: vectors/second of the service batch path at
//! batch sizes 1 / 4 / 16. One multi-vector sweep (`B = A X`) amortizes
//! the matrix traffic over the batch, so throughput must rise with the
//! batch size.
//!
//! Emits `BENCH_pool.json` (override with `RACE_BENCH_OUT`):
//! `{"bench": "pool_latency", "cases": [{matrix, threads, scoped_ms,
//! pool_ms, speedup, nsteps, nunits}], "serve": [{matrix, batch,
//! ms_per_batch, vectors_per_s, speedup_vs_single}]}`.
//! `RACE_BENCH_FULL=1` runs larger variants.

use race::gen;
use race::kernels;
use race::op::{Backend, OpConfig, Operator};
use race::serve::{MatvecService, ServeOptions};
use race::sparse::Csr;
use race::util::bench;
use race::util::json::Json;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let cases: Vec<(&str, Csr)> = if small {
        vec![
            ("stencil2d:64x64", gen::stencil2d_5pt(64, 64)),
            ("graphene:40x40", gen::graphene(40, 40)),
            ("delaunay:40x40", gen::delaunay_like(40, 40, 9)),
        ]
    } else {
        vec![
            ("stencil2d:192x192", gen::stencil2d_5pt(192, 192)),
            ("graphene:96x96", gen::graphene(96, 96)),
            ("delaunay:96x96", gen::delaunay_like(96, 96, 9)),
        ]
    };

    // ---- part 1: scoped-spawn vs persistent pool ----
    let mut rows = Vec::new();
    for (name, a0) in &cases {
        let n = a0.nrows();
        for threads in [2usize, 4] {
            // one handle owns RCM + engine + upper triangle + program +
            // resident pool; the scoped baseline reuses its schedule
            let op = Operator::build(a0, OpConfig::new().threads(threads).backend(Backend::Pool))
                .expect("operator");
            let x: Vec<f64> = (0..n).map(|i| ((i % 97) as f64) * 0.02 - 1.0).collect();
            let xp = op.permute(&x);
            let mut b = vec![0.0; n];
            let s_scoped = bench::bench(&format!("{name}/t{threads}/scoped"), 0.2, || {
                b.iter_mut().for_each(|v| *v = 0.0);
                kernels::symmspmv_race(op.engine(), op.upper(), &xp, &mut b);
                std::hint::black_box(&b);
            });
            let mut b2 = vec![0.0; n];
            let s_pool = bench::bench(&format!("{name}/t{threads}/pool"), 0.2, || {
                op.symmspmv_permuted(&xp, &mut b2).unwrap();
                std::hint::black_box(&b2);
            });
            bench::report(&s_scoped, None);
            bench::report(&s_pool, None);
            // correctness paranoia: both executors agree bit-for-bit
            assert_eq!(b, b2, "{name}/t{threads}: pool result diverges");
            // headline acceptance: the pool never loses to spawn/join
            assert!(
                s_pool.median <= s_scoped.median,
                "{name}/t{threads}: pool {:.3} ms must not exceed scoped {:.3} ms",
                s_pool.median * 1e3,
                s_scoped.median * 1e3
            );
            println!(
                "{name}/t{threads}: scoped {:.3} ms -> pool {:.3} ms ({:.2}x), {} steps / {} units",
                s_scoped.median * 1e3,
                s_pool.median * 1e3,
                s_scoped.median / s_pool.median,
                op.program().nsteps(),
                op.program().nunits()
            );
            rows.push(Json::obj(vec![
                ("matrix", Json::Str(name.to_string())),
                ("threads", Json::Num(threads as f64)),
                ("scoped_ms", Json::Num(s_scoped.median * 1e3)),
                ("pool_ms", Json::Num(s_pool.median * 1e3)),
                ("speedup", Json::Num(s_scoped.median / s_pool.median)),
                ("nsteps", Json::Num(op.program().nsteps() as f64)),
                ("nunits", Json::Num(op.program().nunits() as f64)),
            ]));
        }
    }

    // ---- part 2: serve throughput vs batch size ----
    let mut serve_rows = Vec::new();
    for (name, _) in &cases {
        let opts = ServeOptions {
            matrices: vec![name.to_string()],
            threads: 2,
            small: true,
            ..Default::default()
        };
        let svc = MatvecService::build(&opts).expect("service");
        let n = svc.entries()[0].n;
        let mut per_vector_single = 0.0f64;
        for batch in [1usize, 4, 16] {
            let xs: Vec<Vec<f64>> = (0..batch)
                .map(|j| (0..n).map(|i| ((i * (j + 2)) % 101) as f64 * 0.02 - 1.0).collect())
                .collect();
            let s = bench::bench(&format!("{name}/serve-batch{batch}"), 0.2, || {
                std::hint::black_box(svc.matvec_batch(None, &xs).expect("batch"));
            });
            bench::report(&s, None);
            let per_vector = s.median / batch as f64;
            if batch == 1 {
                per_vector_single = per_vector;
            } else {
                // batching must beat one-vector-at-a-time throughput
                assert!(
                    per_vector < per_vector_single,
                    "{name}/batch{batch}: {:.1} us/vec must undercut single {:.1} us/vec",
                    per_vector * 1e6,
                    per_vector_single * 1e6
                );
            }
            println!(
                "{name}/batch{batch}: {:.3} ms/batch = {:.0} vectors/s ({:.2}x vs single)",
                s.median * 1e3,
                batch as f64 / s.median,
                per_vector_single / per_vector
            );
            serve_rows.push(Json::obj(vec![
                ("matrix", Json::Str(name.to_string())),
                ("batch", Json::Num(batch as f64)),
                ("ms_per_batch", Json::Num(s.median * 1e3)),
                ("vectors_per_s", Json::Num(batch as f64 / s.median)),
                ("speedup_vs_single", Json::Num(per_vector_single / per_vector)),
            ]));
        }
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("pool_latency".to_string())),
        ("cases", Json::Arr(rows)),
        ("serve", Json::Arr(serve_rows)),
    ]);
    let path = race::obs::baseline::write_bench("BENCH_pool.json", out, None)
        .expect("write BENCH_pool.json");
    println!("wrote {path}");
}
