//! Fig. 22: the delaunay_n24 vectorization study — SymmSpMV with the
//! unrolled ("vectorized") inner loop vs. the scalar variant, real
//! wallclock on the host plus the SKX-socket simulation. The paper finds
//! scalar code 15% FASTER for this matrix (avg inner loop length ~3).

use race::cachesim;
use race::gen;
use race::kernels;
use race::machine;
use race::op::{self, OpConfig, Operator};
use race::sim;
use race::util::bench::bench;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let e = gen::corpus_entry("delaunay_n24").unwrap();
    let a0 = (e.build)(small);
    let perm = race::graph::rcm(&a0);
    let a = a0.permute_symmetric(&perm);
    let upper = op::upper(&a);
    let n = a.nrows();
    println!(
        "delaunay analogue: {} rows, {} nnz, N_nzr = {:.2} (upper: {:.2})",
        n,
        a.nnz(),
        a.nnzr(),
        upper.nnzr()
    );
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
    let mut b = vec![0.0; n];
    let flops = 2.0 * a.nnz() as f64;

    let s_vec = bench("unrolled", 0.3, || {
        b.iter_mut().for_each(|v| *v = 0.0);
        kernels::symmspmv_range_unrolled(&upper, &x, &mut b, 0, n);
    });
    let s_scalar = bench("scalar", 0.3, || {
        b.iter_mut().for_each(|v| *v = 0.0);
        kernels::symmspmv_range_scalar(&upper, &x, &mut b, 0, n);
    });
    std::hint::black_box(&b);
    println!(
        "host single core: unrolled {:.3} GF/s, scalar {:.3} GF/s (scalar/unrolled = {:.2})",
        s_vec.gflops(flops),
        s_scalar.gflops(flops),
        s_vec.median / s_scalar.median
    );
    println!("(paper: scalar ~1.15x faster on SKX for this matrix class)");

    // socket-level simulation: same schedule, core_flops calibrated from
    // the two host kernels' relative speed
    let m = machine::skx();
    let rop = Operator::build(&a, OpConfig::new().rcm(false).threads(m.cores)).unwrap();
    let tr = cachesim::measure_symmspmv_traffic(rop.upper(), a.nnz(), &m);
    let mut m_scalar = m.clone();
    m_scalar.core_flops = m.core_flops * s_vec.median / s_scalar.median;
    let g_vec = sim::simulate_race(&m, rop.engine(), rop.upper(), tr.bytes_total, a.nnz()).gflops;
    let g_scalar =
        sim::simulate_race(&m_scalar, rop.engine(), rop.upper(), tr.bytes_total, a.nnz()).gflops;
    let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);
    println!("\nSKX socket simulation (20 cores):");
    println!("  SymmSpMV unrolled: {g_vec:.2} GF/s");
    println!("  SymmSpMV scalar:   {g_scalar:.2} GF/s");
    println!(
        "  SpMV baseline:     {:.2} GF/s",
        sim::simulate_spmv(&m, &a, m.cores, tr_spmv.bytes_total).gflops
    );
}
