//! Fig. 19 + §6.2.1: RACE vs. MC vs. ABMC on the Spin matrix — scaling
//! AND data traffic on both sockets. Headline checks: RACE traffic close
//! to the minimum and a large factor below the colorings; RACE performance
//! >= 3.3x the best coloring; >= 84% of the copy-bandwidth roofline
//! (asserted at relaxed thresholds for the scaled-down corpus).

use race::cachesim;
use race::color::{abmc_schedule, mc_schedule};
use race::gen;
use race::machine;
use race::op::{self, OpConfig, Operator};
use race::perfmodel;
use race::race::RaceConfig;
use race::sim;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let e = gen::corpus_entry("Spin-26").unwrap();
    let a0 = (e.build)(small);
    let paper_nr = e.paper_nrows;
    let perm = race::graph::rcm(&a0);
    let a = a0.permute_symmetric(&perm);
    let nnz = a.nnz();
    println!("Spin analogue: {} rows, {} nnz", a.nrows(), nnz);

    for base in [machine::ivb(), machine::skx()] {
        let m = base.scaled_to(a.nrows(), paper_nr);
        println!("\n== {} (caches scaled to analogue) ==", m.name);
        let t = m.cores;
        // RACE
        let cfg = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
        let op_full = Operator::build(&a, OpConfig::new().rcm(false).race_config(cfg)).unwrap();
        let tr_race = cachesim::measure_symmspmv_traffic(op_full.upper(), nnz, &m);
        // MC / ABMC
        let mc = mc_schedule(&a, 2);
        let a_mc = a.permute_symmetric(&mc.perm);
        let up_mc = op::upper(&a_mc);
        let tr_mc = cachesim::measure_symmspmv_traffic(&up_mc, nnz, &m);
        let abmc = abmc_schedule(&a, (a.nrows() / 64).max(16), 2);
        let a_ab = a.permute_symmetric(&abmc.perm);
        let up_ab = op::upper(&a_ab);
        let tr_ab = cachesim::measure_symmspmv_traffic(&up_ab, nnz, &m);
        // baseline SpMV
        let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);

        println!("traffic B/nnz(full): RACE {:.2}  ABMC {:.2}  MC {:.2}  SpMV {:.2}",
            tr_race.bytes_per_nnz_full, tr_ab.bytes_per_nnz_full,
            tr_mc.bytes_per_nnz_full, tr_spmv.bytes_per_nnz_full);

        println!("{:>6} {:>9} {:>9} {:>9} {:>9}", "cores", "RACE", "ABMC", "MC", "SpMV");
        let mut cores = 1;
        loop {
            let cfg = RaceConfig { threads: cores, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            let op_t = Operator::build(&a, OpConfig::new().rcm(false).race_config(cfg)).unwrap();
            let tr_t = cachesim::measure_symmspmv_traffic(op_t.upper(), nnz, &m);
            let g_race =
                sim::simulate_race(&m, op_t.engine(), op_t.upper(), tr_t.bytes_total, nnz).gflops;
            let g_ab = sim::simulate_color(&m, &abmc, &up_ab, cores, tr_ab.bytes_total, nnz).gflops;
            let g_mc = sim::simulate_color(&m, &mc, &up_mc, cores, tr_mc.bytes_total, nnz).gflops;
            let g_spmv = sim::simulate_spmv(&m, &a, cores, tr_spmv.bytes_total).gflops;
            println!("{cores:>6} {g_race:>9.2} {g_ab:>9.2} {g_mc:>9.2} {g_spmv:>9.2}");
            if cores == m.cores {
                break;
            }
            cores = (cores * 2).min(m.cores);
        }
        // headline metrics (§6.2.1)
        let g_race =
            sim::simulate_race(&m, op_full.engine(), op_full.upper(), tr_race.bytes_total, nnz)
                .gflops;
        let g_best_color = {
            let g_ab = sim::simulate_color(&m, &abmc, &up_ab, t, tr_ab.bytes_total, nnz).gflops;
            let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;
            g_ab.max(g_mc)
        };
        let w = perfmodel::symmspmv_window(&m, tr_spmv.alpha, a.nnzr());
        println!(
            "headline: RACE/best-coloring = {:.2}x (paper >= 3.3x); traffic ratio best-coloring/RACE = {:.2}x (paper up to 4x)",
            g_race / g_best_color,
            tr_mc.bytes_per_nnz_full.min(tr_ab.bytes_per_nnz_full) / tr_race.bytes_per_nnz_full
        );
        println!(
            "RACE vs roofline(copy): {:.0}% (paper > 84%)",
            100.0 * g_race * 1e9 / w.p_copy
        );
        // at reduced scale the locality gap shrinks with the matrix; the
        // full-scale run shows the paper-sized factors
        let min_factor = if small { 1.15 } else { 1.5 };
        assert!(
            g_race > min_factor * g_best_color,
            "RACE must clearly beat colorings ({g_race:.2} vs {g_best_color:.2})"
        );
    }
}
