//! Solver convergence + traffic bench: f64 CG vs mixed-precision
//! iterative refinement over the generator corpus, every matrix
//! certified SPD by a Gershgorin shift where needed.
//!
//! For each corpus matrix the bench solves the same system twice on one
//! `Operator` (pool backend, default packed storage) and charges each
//! solve its cachesim-measured traffic: `matvecs × bytes-per-sweep` at
//! the precision each sweep actually streamed (f64 pack — or CSR where
//! the pack is infeasible — for full-precision sweeps, f32 pack for the
//! mixed inner sweeps). That is the Roofline-level answer to "does the
//! ValPrec knob pay inside a solver": same tolerance, fewer bytes.
//!
//! Emits `BENCH_solver.json` (override with `RACE_BENCH_OUT`):
//! `{"bench": "solver_convergence", "machine": .., "cases": [{matrix,
//! nrows, spd_shift, f64_iterations, f64_matvecs, f64_seconds,
//! f64_traffic_bytes, mixed_outer, mixed_matvecs_f64, mixed_matvecs_f32,
//! mixed_fell_back, mixed_used_f32, mixed_seconds, mixed_traffic_bytes,
//! traffic_ratio, converged}], "summary": {mean_traffic_ratio,
//! feasible_mean_traffic_ratio, converged, total}}`.
//!
//! Acceptance (asserted here, so CI catches regressions): every solve on
//! the corpus reaches the tolerance (true residual, reference SpMV), and
//! mixed precision spends measurably less traffic than f64 CG on the
//! corpus mean.
//!
//! `RACE_BENCH_FULL=1` runs the bench-scale corpus variants.

use race::cachesim;
use race::gen;
use race::machine;
use race::op::{OpConfig, Operator};
use race::solver::{self, Method, SolveConfig};
use race::util::json::Json;

const TOL: f64 = 1e-8;

fn true_rel_residual(a: &race::sparse::Csr, rhs: &[f64], x: &[f64]) -> f64 {
    let ax = a.spmv_ref(x);
    let num: f64 = ax.iter().zip(rhs).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt();
    let den: f64 = rhs.iter().map(|v| v * v).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let m = machine::skx();
    let mut rows = Vec::new();
    let (mut ratio_sum, mut feasible_ratio_sum) = (0.0f64, 0.0f64);
    let (mut total, mut feasible, mut converged) = (0usize, 0usize, 0usize);
    for e in gen::corpus() {
        let a0 = (e.build)(small);
        // certify SPD: shift the Gershgorin interval to a bounded
        // condition estimate (no-op for the diagonally dominant families)
        let (a, shift) = solver::make_spd(&a0, 0.02);
        let op = Operator::build(&a, OpConfig::new().threads(4)).expect("operator build");
        let n = op.n();
        let rhs: Vec<f64> = (0..n)
            .map(|i| (i as f64 * 0.013).sin() + if i == n / 2 { 10.0 } else { 0.0 })
            .collect();

        let f64_sol = op
            .solve(&rhs, &SolveConfig::new().tol(TOL).max_iter(20_000))
            .expect("f64 CG solve");
        let mixed = op
            .solve(&rhs, &SolveConfig::new().method(Method::Mixed).tol(TOL).max_iter(20_000))
            .expect("mixed solve");

        // acceptance: both reach the tolerance, measured honestly
        let r64 = true_rel_residual(&a, &rhs, &f64_sol.x);
        let rmx = true_rel_residual(&a, &rhs, &mixed.x);
        assert!(
            f64_sol.converged && r64 <= TOL * 1.01,
            "{}: f64 CG residual {r64:.3e}",
            e.name
        );
        assert!(
            mixed.converged && rmx <= TOL * 1.01,
            "{}: mixed residual {rmx:.3e}",
            e.name
        );
        converged += 1;

        // cachesim traffic per sweep on the storage each solve streamed:
        // the operator's default is the f64 pack (CSR where infeasible);
        // mixed inner sweeps stream the f32 pack when feasible
        let cmp = cachesim::compare_symmspmv_pack_traffic(op.upper(), a.nnz(), &m);
        let sweep_f64 =
            if cmp.feasible() { cmp.tr_f64.bytes_total } else { cmp.tr_csr.bytes_total };
        let sweep_f32 = if mixed.used_f32 { cmp.tr_f32.bytes_total } else { sweep_f64 };
        let traffic_f64 = f64_sol.matvecs as u64 * sweep_f64;
        let traffic_mixed =
            mixed.matvecs as u64 * sweep_f64 + mixed.matvecs_f32 as u64 * sweep_f32;
        let ratio = traffic_mixed as f64 / traffic_f64 as f64;
        total += 1;
        ratio_sum += ratio;
        if mixed.used_f32 {
            feasible += 1;
            feasible_ratio_sum += ratio;
        }
        println!(
            "{:<26} f64 CG {:>5} mv / {:>7.1} MB   mixed {:>4}+{:<5} mv / {:>7.1} MB   \
             ratio {:.2}{}{}",
            e.name,
            f64_sol.matvecs,
            traffic_f64 as f64 / 1e6,
            mixed.matvecs,
            mixed.matvecs_f32,
            traffic_mixed as f64 / 1e6,
            ratio,
            if mixed.fell_back { "  [fell back]" } else { "" },
            if mixed.used_f32 { "" } else { "  [f32 pack infeasible]" }
        );
        rows.push(Json::obj(vec![
            ("matrix", Json::Str(e.name.to_string())),
            ("nrows", Json::Num(n as f64)),
            ("spd_shift", Json::Num(shift)),
            ("f64_iterations", Json::Num(f64_sol.iterations as f64)),
            ("f64_matvecs", Json::Num(f64_sol.matvecs as f64)),
            ("f64_seconds", Json::Num(f64_sol.seconds)),
            ("f64_traffic_bytes", Json::Num(traffic_f64 as f64)),
            ("mixed_outer", Json::Num(mixed.iterations as f64)),
            ("mixed_matvecs_f64", Json::Num(mixed.matvecs as f64)),
            ("mixed_matvecs_f32", Json::Num(mixed.matvecs_f32 as f64)),
            ("mixed_fell_back", Json::Bool(mixed.fell_back)),
            ("mixed_used_f32", Json::Bool(mixed.used_f32)),
            ("mixed_seconds", Json::Num(mixed.seconds)),
            ("mixed_traffic_bytes", Json::Num(traffic_mixed as f64)),
            ("traffic_ratio", Json::Num(ratio)),
            ("converged", Json::Bool(true)),
        ]));
    }
    let mean_ratio = ratio_sum / total.max(1) as f64;
    let feasible_mean = feasible_ratio_sum / feasible.max(1) as f64;
    println!(
        "corpus mean traffic ratio (mixed / f64): {mean_ratio:.3} over {total} matrices \
         ({feasible_mean:.3} over the {feasible} f32-pack-feasible ones)"
    );
    // headline acceptance: same tolerance, measurably less traffic on
    // the corpus mean
    assert_eq!(converged, total, "every corpus solve must converge");
    assert!(
        mean_ratio < 0.95,
        "mixed precision must cut solver traffic on the corpus mean (ratio {mean_ratio:.3})"
    );
    assert!(
        feasible_mean < 0.85,
        "pack-feasible matrices must see a clear cut (ratio {feasible_mean:.3})"
    );
    let out = Json::obj(vec![
        ("bench", Json::Str("solver_convergence".to_string())),
        ("machine", Json::Str(m.name.clone())),
        ("tol", Json::Num(TOL)),
        ("cases", Json::Arr(rows)),
        (
            "summary",
            Json::obj(vec![
                ("mean_traffic_ratio", Json::Num(mean_ratio)),
                ("feasible_mean_traffic_ratio", Json::Num(feasible_mean)),
                ("converged", Json::Num(converged as f64)),
                ("total", Json::Num(total as f64)),
            ]),
        ),
    ]);
    let path = race::obs::baseline::write_bench("BENCH_solver.json", out, Some(&m))
        .expect("write BENCH_solver.json");
    println!("wrote {path}");
}
