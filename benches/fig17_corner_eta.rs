//! Fig. 17: N_t^eff and η vs. N_t for the four corner-case matrices
//! (crankseg_1, inline_1, parabolic_fem, Graphene-4096) on up to 20
//! threads (one Skylake SP socket), with the experiment-run settings.

use race::gen;
use race::race::{RaceConfig, RaceEngine};

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    for name in ["crankseg_1", "inline_1", "parabolic_fem", "Graphene-4096"] {
        let e = gen::corpus_entry(name).unwrap();
        let a0 = (e.build)(small);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        println!("\n== {} ({} rows, {} nnz) ==", name, a.nrows(), a.nnz());
        println!("{:>6} {:>8} {:>8}", "N_t", "eta", "N_t_eff");
        for t in 1..=20usize {
            if t > 2 && t % 2 != 0 && t != 5 && t != 9 && t != 15 {
                continue; // sample like the paper's plot density
            }
            let cfg = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            match RaceEngine::build(&a, &cfg) {
                Ok(eng) => println!(
                    "{t:>6} {:>8.3} {:>8.2}",
                    eng.efficiency(),
                    eng.effective_threads()
                ),
                Err(err) => println!("{t:>6}  build failed: {err}"),
            }
        }
    }
    println!("\n(paper: crankseg saturates near N_t_eff ~ 6-10; graphene nearly ideal)");
}
