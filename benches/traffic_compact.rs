//! Traffic-compact storage bench: CSR vs the delta-compressed
//! [`race::sparse::CsrPack`] over the whole RCM-permuted corpus —
//! cachesim-measured SymmSpMV bytes/nnz (the Roofline quantity the paper
//! optimizes) plus host wallclock for the serial range kernel, per
//! matrix and per value precision.
//!
//! Emits `BENCH_traffic.json` (override with `RACE_BENCH_OUT`):
//! `{"bench": "traffic_compact", "machine": .., "cases": [{matrix,
//! nrows, nnz_upper, bw_rcm, escapes, rows_escaped, feasible_f64,
//! csr_bytes_per_nnz, pack_f64_bytes_per_nnz, pack_f32_bytes_per_nnz,
//! cut_f64, cut_f32, csr_gfs, pack_f64_gfs, pack_f32_gfs}],
//! "summary": {mean_cut_f64, mean_cut_f32, feasible}}`.
//!
//! Acceptance (asserted here, so CI catches regressions): over the
//! pack-feasible corpus the mean traffic cut of the f32 pack is >= 20%,
//! the f64 pack strictly undercuts CSR on every feasible matrix, and the
//! f64 pack kernel returns bit-identical results.
//!
//! `RACE_BENCH_FULL=1` runs the bench-scale corpus variants.

use race::cachesim;
use race::gen;
use race::kernels;
use race::machine;
use race::util::bench;
use race::util::json::Json;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let m = machine::skx();
    let mut rows = Vec::new();
    let (mut cut64_sum, mut cut32_sum, mut feasible) = (0.0f64, 0.0f64, 0usize);
    let mut total = 0usize;
    for e in gen::corpus() {
        let a0 = (e.build)(small);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let upper = a.upper_triangle();
        let n = a.nrows();

        // simulated memory traffic (the headline metric) — the same
        // shared comparison `race-cli pack-stats` prints
        let cmp = cachesim::compare_symmspmv_pack_traffic(&upper, a.nnz(), &m);
        let (pack64, pack32) = (&cmp.pack_f64, &cmp.pack_f32);
        let (tr_csr, tr_p64, tr_p32) = (&cmp.tr_csr, &cmp.tr_f64, &cmp.tr_f32);
        let (cut64, cut32) = (cmp.cut_f64(), cmp.cut_f32());

        // host wallclock of the serial range kernel on each encoding
        let x: Vec<f64> = (0..n).map(|i| ((i % 97) as f64) * 0.02 - 1.0).collect();
        let mut b = vec![0.0; n];
        let flops = 2.0 * a.nnz() as f64;
        let s_csr = bench::bench(&format!("{}/csr", e.name), 0.05, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range(&upper, &x, &mut b, 0, n);
        });
        let want = b.clone();
        let s_p64 = bench::bench(&format!("{}/pack-f64", e.name), 0.05, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_pack(pack64, &x, &mut b, 0, n);
        });
        // correctness paranoia: the f64 pack result is bit-identical
        assert_eq!(want, b, "{}: f64 pack diverged from CSR", e.name);
        let s_p32 = bench::bench(&format!("{}/pack-f32", e.name), 0.05, || {
            b.iter_mut().for_each(|v| *v = 0.0);
            kernels::symmspmv_range_pack(pack32, &x, &mut b, 0, n);
        });
        std::hint::black_box(&b);

        total += 1;
        if cmp.feasible() {
            feasible += 1;
            cut64_sum += cut64;
            cut32_sum += cut32;
            assert!(
                tr_p64.bytes_total < tr_csr.bytes_total,
                "{}: feasible f64 pack must undercut CSR traffic ({} vs {})",
                e.name,
                tr_p64.bytes_total,
                tr_csr.bytes_total
            );
        }
        let st = cmp.stats();
        println!(
            "{:<26} traffic {:>6.2} -> {:>6.2} (f64) / {:>6.2} (f32) B/nnz  \
             cut {:>5.1}% / {:>5.1}%  esc {} ({} rows){}",
            e.name,
            tr_csr.bytes_per_nnz_full,
            tr_p64.bytes_per_nnz_full,
            tr_p32.bytes_per_nnz_full,
            cut64 * 100.0,
            cut32 * 100.0,
            st.escapes,
            st.rows_escaped,
            if cmp.feasible() { "" } else { "  [fallback: csr]" }
        );
        rows.push(Json::obj(vec![
            ("matrix", Json::Str(e.name.to_string())),
            ("nrows", Json::Num(n as f64)),
            ("nnz_upper", Json::Num(upper.nnz() as f64)),
            ("bw_rcm", Json::Num(a.bandwidth() as f64)),
            ("escapes", Json::Num(st.escapes as f64)),
            ("rows_escaped", Json::Num(st.rows_escaped as f64)),
            ("feasible_f64", Json::Bool(cmp.feasible())),
            ("csr_bytes_per_nnz", Json::Num(tr_csr.bytes_per_nnz_full)),
            ("pack_f64_bytes_per_nnz", Json::Num(tr_p64.bytes_per_nnz_full)),
            ("pack_f32_bytes_per_nnz", Json::Num(tr_p32.bytes_per_nnz_full)),
            ("cut_f64", Json::Num(cut64)),
            ("cut_f32", Json::Num(cut32)),
            ("csr_gfs", Json::Num(s_csr.gflops(flops))),
            ("pack_f64_gfs", Json::Num(s_p64.gflops(flops))),
            ("pack_f32_gfs", Json::Num(s_p32.gflops(flops))),
        ]));
    }
    let mean64 = cut64_sum / feasible.max(1) as f64;
    let mean32 = cut32_sum / feasible.max(1) as f64;
    println!(
        "corpus mean traffic cut over {feasible}/{total} pack-feasible matrices: \
         {:.1}% (f64) / {:.1}% (f32)",
        mean64 * 100.0,
        mean32 * 100.0
    );
    // headline acceptance: the compact engine must cut >= 20% of the
    // measured SymmSpMV traffic (single-precision pack), and most of the
    // corpus must be pack-feasible after RCM
    assert!(feasible * 2 > total, "only {feasible}/{total} matrices pack-feasible");
    assert!(mean32 >= 0.20, "mean f32 traffic cut {:.3} below the 20% acceptance bar", mean32);
    assert!(mean64 > 0.0, "f64 pack must cut traffic on average");
    let out = Json::obj(vec![
        ("bench", Json::Str("traffic_compact".to_string())),
        ("machine", Json::Str(m.name.clone())),
        ("cases", Json::Arr(rows)),
        (
            "summary",
            Json::obj(vec![
                ("mean_cut_f64", Json::Num(mean64)),
                ("mean_cut_f32", Json::Num(mean32)),
                ("feasible", Json::Num(feasible as f64)),
                ("total", Json::Num(total as f64)),
            ]),
        ),
    ]);
    let path = race::obs::baseline::write_bench("BENCH_traffic.json", out, Some(&m))
        .expect("write BENCH_traffic.json");
    println!("wrote {path}");
}
