//! Fig. 1: attained memory bandwidth vs. working-set size (load-only and
//! copy), the likwid-bench substitute measured on the host. The paper's
//! IVB/SKX curves are tabulated from their Table 1 asymptotes for
//! comparison.

use race::machine;
use race::util::bench::bench;

fn main() {
    println!("== Fig. 1: bandwidth vs data-set size (host measurement) ==");
    println!("{:>10} {:>12} {:>12}", "size", "load GB/s", "copy GB/s");
    for mb in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let n = mb * (1 << 20) / 8;
        let a = vec![1.0f64; n];
        let mut b = vec![0.0f64; n];
        let mut sink = 0.0;
        let load = bench(&format!("load {mb}MB"), 0.2, || {
            let mut s = 0.0;
            for c in a.chunks(4096) {
                s += c.iter().sum::<f64>();
            }
            sink += s;
        });
        let copy = bench(&format!("copy {mb}MB"), 0.2, || {
            b.copy_from_slice(&a);
        });
        std::hint::black_box((&b, sink));
        println!(
            "{:>8}MB {:>12.2} {:>12.2}",
            mb,
            n as f64 * 8.0 / load.median / 1e9,
            2.0 * n as f64 * 8.0 / copy.median / 1e9
        );
    }
    println!("\npaper Table 1 asymptotes for the modeled sockets:");
    for m in [machine::ivb(), machine::skx()] {
        println!(
            "  {:<4} load {:.0} GB/s  copy {:.0} GB/s  (eff. cache {} MB)",
            m.name,
            m.bw_load / 1e9,
            m.bw_copy / 1e9,
            m.effective_cache() / (1 << 20)
        );
    }
}
