//! Tables 1-3: machine specs, corpus properties, and the α / intensity
//! table — the non-figure artifacts of the paper's evaluation.

use race::cachesim;
use race::gen;
use race::machine;
use race::perfmodel;
use race::sparse::MatrixStats;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();

    println!("== Table 1: machines ==");
    println!(
        "{:<6} {:>5} {:>10} {:>10} {:>9} {:>9} {:>10}",
        "name", "cores", "bw_load", "bw_copy", "L2/core", "L3", "eff.cache"
    );
    for m in [machine::ivb(), machine::skx(), machine::host(32)] {
        println!(
            "{:<6} {:>5} {:>8.1}GB {:>8.1}GB {:>7}KB {:>7}MB {:>8}MB",
            m.name,
            m.cores,
            m.bw_load / 1e9,
            m.bw_copy / 1e9,
            m.l2 / 1024,
            m.l3 / (1 << 20),
            m.effective_cache() / (1 << 20)
        );
    }

    println!("\n== Table 2: corpus (structural analogues, laptop scale) ==");
    println!(
        "{:>3} {:<26} {:>9} {:>10} {:>7} {:>8} {:>8} {:>9}",
        "idx", "matrix", "N_r", "N_nz", "N_nzr", "bw", "bw_rcm", "symm MB"
    );
    let mut cache = Vec::new();
    for e in gen::corpus() {
        let a = (e.build)(small);
        let s = MatrixStats::compute(e.name, &a);
        println!(
            "{:>3} {:<26} {:>9} {:>10} {:>7.2} {:>8} {:>8} {:>9.1}",
            e.index,
            e.name,
            s.nrows,
            s.nnz,
            s.nnzr,
            s.bw,
            s.bw_rcm,
            s.sym_bytes as f64 / 1e6
        );
        cache.push((e.name, a, s));
    }

    println!("\n== Table 3: alpha and intensities (both machines) ==");
    println!(
        "{:>3} {:<26} {:>9} {:>9} {:>10} {:>10}",
        "idx", "matrix", "a_opt", "I_SpMV", "a_meas skx", "a_meas ivb"
    );
    let entries = gen::corpus();
    for (i, (name, a, s)) in cache.iter().enumerate() {
        let perm = race::graph::rcm(a);
        let arc = a.permute_symmetric(&perm);
        let skx = machine::skx().scaled_to(a.nrows(), entries[i].paper_nrows);
        let ivb = machine::ivb().scaled_to(a.nrows(), entries[i].paper_nrows);
        let a_skx = cachesim::measure_spmv_traffic(&arc, &skx).alpha;
        let a_ivb = cachesim::measure_spmv_traffic(&arc, &ivb).alpha;
        let aopt = perfmodel::alpha_opt_spmv(s.nnzr);
        println!(
            "{:>3} {:<26} {:>9.4} {:>9.4} {:>10.4} {:>10.4}",
            i + 1,
            name,
            aopt,
            perfmodel::intensity_spmv(aopt, s.nnzr),
            a_skx,
            a_ivb
        );
    }
}
