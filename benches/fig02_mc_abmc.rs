//! Fig. 2: SymmSpMV with MC and ABMC vs. SpMV on the Spin matrix —
//! scaling over cores and measured data traffic per nonzero, on both
//! machine models. Reproduces the paper's finding: MC ~3x the SpMV
//! traffic, ABMC in between, both far below the roofline expectation.

use race::cachesim;
use race::color::{abmc_schedule, mc_schedule};
use race::gen;
use race::graph;
use race::machine;
use race::op;
use race::perfmodel;
use race::sim;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let e = gen::corpus_entry("Spin-26").unwrap();
    let a0 = (e.build)(small);
    let paper_nr = e.paper_nrows;
    let perm = graph::rcm(&a0);
    let a = a0.permute_symmetric(&perm);
    let nnz = a.nnz();
    println!("Spin chain analogue: {} rows, {} nnz (RCM preordered)", a.nrows(), nnz);

    for base in [machine::ivb(), machine::skx()] {
        // scale caches to the analogue size (DESIGN.md §Substitutions)
        let m = base.scaled_to(a.nrows(), paper_nr);
        println!("\n== {} (caches scaled to analogue) ==", m.name);
        // schedules + traffic (independent of thread count)
        let mc = mc_schedule(&a, 2);
        let a_mc = a.permute_symmetric(&mc.perm);
        let up_mc = op::upper(&a_mc);
        let tr_mc = cachesim::measure_symmspmv_traffic(&up_mc, nnz, &m);

        let abmc = abmc_schedule(&a, (a.nrows() / 64).max(16), 2);
        let a_ab = a.permute_symmetric(&abmc.perm);
        let up_ab = op::upper(&a_ab);
        let tr_ab = cachesim::measure_symmspmv_traffic(&up_ab, nnz, &m);

        let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);
        let tr_symm_ideal = cachesim::measure_symmspmv_traffic(&op::upper(&a), nnz, &m);

        println!("traffic per full-matrix nonzero (paper Fig. 2b/2d):");
        println!("  SpMV          {:>7.2} B/nnz (alpha={:.3})", tr_spmv.bytes_per_nnz_full, tr_spmv.alpha);
        println!("  SymmSpMV(nat) {:>7.2} B/nnz", tr_symm_ideal.bytes_per_nnz_full);
        println!("  SymmSpMV MC   {:>7.2} B/nnz ({:.1}x SpMV)", tr_mc.bytes_per_nnz_full, tr_mc.bytes_per_nnz_full / tr_spmv.bytes_per_nnz_full);
        println!("  SymmSpMV ABMC {:>7.2} B/nnz ({:.1}x SpMV)", tr_ab.bytes_per_nnz_full, tr_ab.bytes_per_nnz_full / tr_spmv.bytes_per_nnz_full);

        let w = perfmodel::symmspmv_window(&m, tr_spmv.alpha, a.nnzr());
        println!(
            "roofline SymmSpMV window: {:.2}..{:.2} GF/s",
            w.p_copy / 1e9,
            w.p_load / 1e9
        );
        println!("scaling (GF/s, paper Fig. 2a/2c):");
        println!("{:>7} {:>9} {:>9} {:>9}", "cores", "SpMV", "MC", "ABMC");
        let mut t = 1;
        while t <= m.cores {
            let g_spmv = sim::simulate_spmv(&m, &a, t, tr_spmv.bytes_total).gflops;
            let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;
            let g_ab = sim::simulate_color(&m, &abmc, &up_ab, t, tr_ab.bytes_total, nnz).gflops;
            println!("{t:>7} {g_spmv:>9.2} {g_mc:>9.2} {g_ab:>9.2}");
            t *= 2;
        }
        // full socket
        let t = m.cores;
        let g_spmv = sim::simulate_spmv(&m, &a, t, tr_spmv.bytes_total).gflops;
        let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;
        let g_ab = sim::simulate_color(&m, &abmc, &up_ab, t, tr_ab.bytes_total, nnz).gflops;
        println!("{t:>7} {g_spmv:>9.2} {g_mc:>9.2} {g_ab:>9.2}   <- full socket");
        assert!(g_mc < g_spmv, "paper finding: MC SymmSpMV loses to SpMV");
    }
}
