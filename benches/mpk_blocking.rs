//! MPK level-blocking bench: `p` naive back-to-back SpMV sweeps vs. the
//! level-blocked diamond schedule — host wallclock GF/s plus simulated
//! memory traffic per nonzero application, on a small corpus (one stencil,
//! one lattice, one irregular graph). Both paths run through one
//! [`race::op::Operator`] handle (serial backend — the blocking win is a
//! cache effect, not a threading one).
//!
//! Emits `BENCH_mpk.json` (override the path with `RACE_BENCH_OUT`) so the
//! perf trajectory is machine-readable from this PR onward:
//! `{"bench": "mpk_blocking", "power": p, "cases": [{matrix, naive_gfs,
//! mpk_gfs, speedup, naive_bytes_per_nnz, mpk_bytes_per_nnz,
//! traffic_ratio, nlevels, nblocks}]}`.
//!
//! `RACE_BENCH_FULL=1` runs the larger variants.

use race::cachesim;
use race::gen;
use race::kernels;
use race::machine;
use race::mpk::powers_ref;
use race::op::{self, Backend, OpConfig, Operator};
use race::sparse::Csr;
use race::util::bench;
use race::util::json::Json;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let p = 4usize;
    let cases: Vec<(&str, Csr)> = if small {
        vec![
            ("stencil2d:96x96", gen::stencil2d_5pt(96, 96)),
            ("graphene:48x48", gen::graphene(48, 48)),
            ("delaunay:48x48", gen::delaunay_like(48, 48, 9)),
        ]
    } else {
        vec![
            ("stencil2d:256x256", gen::stencil2d_5pt(256, 256)),
            ("graphene:128x128", gen::graphene(128, 128)),
            ("delaunay:128x128", gen::delaunay_like(128, 128, 9)),
        ]
    };
    let mut rows = Vec::new();
    for (name, a0) in cases {
        // scale the simulated cache so the matrix working set exceeds it —
        // the regime where blocking matters (the paper-scale situation)
        let m = machine::skx().under_pressure(a0.crs_bytes(), 4);
        let op = Operator::build(
            &a0,
            OpConfig::new()
                .threads(1)
                .backend(Backend::Serial)
                .cache_bytes(m.effective_cache() / 2),
        )
        .expect("operator");
        let h = op.mpk(p).expect("plan");
        let plan = h.plan();
        assert!(plan.verify(), "{name}: invalid plan");

        let ap = plan.permuted_matrix();
        // naive measured on the same level-permuted matrix: the ratio
        // isolates blocking from ordering effects
        let tr_blk = cachesim::measure_mpk_traffic(plan, &m);
        let tr_nv = cachesim::measure_spmv_powers_traffic(ap, p, &m);

        let x: Vec<f64> = (0..a0.nrows()).map(|i| ((i % 97) as f64) * 0.02 - 1.0).collect();
        let xp = h.permute(&x);
        let flops = 2.0 * a0.nnz() as f64 * p as f64;
        let s_nv = bench::bench(&format!("{name}/naive-{p}-sweeps"), 0.2, || {
            std::hint::black_box(kernels::spmv_powers(ap, &xp, p, 1));
        });
        let s_blk = bench::bench(&format!("{name}/mpk-blocked"), 0.2, || {
            std::hint::black_box(op.powers_permuted(&h, &xp));
        });
        bench::report(&s_nv, Some(flops));
        bench::report(&s_blk, Some(flops));

        // correctness paranoia: blocked result equals p reference sweeps,
        // compared in logical order through the facade
        let want = powers_ref(&a0, &x, p);
        let ys = op.powers(&x, p).expect("powers");
        let err = op::rel_err(&want[p - 1], &ys[p - 1]);
        assert!(err <= 1e-9, "{name}: vector-relative error {err:.2e}");
        // headline acceptance: strictly fewer bytes per nonzero application
        assert!(
            tr_blk.bytes_per_nnz_full < tr_nv.bytes_per_nnz_full,
            "{name}: blocked traffic {:.2} must undercut naive {:.2}",
            tr_blk.bytes_per_nnz_full,
            tr_nv.bytes_per_nnz_full
        );
        println!(
            "{name}: traffic {:.2} -> {:.2} B/nnz-app ({:.2}x), {} levels in {} blocks",
            tr_nv.bytes_per_nnz_full,
            tr_blk.bytes_per_nnz_full,
            tr_nv.bytes_per_nnz_full / tr_blk.bytes_per_nnz_full,
            plan.nlevels,
            plan.nblocks()
        );
        rows.push(Json::obj(vec![
            ("matrix", Json::Str(name.to_string())),
            ("naive_gfs", Json::Num(s_nv.gflops(flops))),
            ("mpk_gfs", Json::Num(s_blk.gflops(flops))),
            ("speedup", Json::Num(s_nv.median / s_blk.median)),
            ("naive_bytes_per_nnz", Json::Num(tr_nv.bytes_per_nnz_full)),
            ("mpk_bytes_per_nnz", Json::Num(tr_blk.bytes_per_nnz_full)),
            (
                "traffic_ratio",
                Json::Num(tr_nv.bytes_per_nnz_full / tr_blk.bytes_per_nnz_full),
            ),
            ("nlevels", Json::Num(plan.nlevels as f64)),
            ("nblocks", Json::Num(plan.nblocks() as f64)),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::Str("mpk_blocking".to_string())),
        ("power", Json::Num(p as f64)),
        ("cases", Json::Arr(rows)),
    ]);
    let path = race::obs::baseline::write_bench("BENCH_mpk.json", out, None)
        .expect("write BENCH_mpk.json");
    println!("wrote {path}");
}
