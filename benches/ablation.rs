//! Ablation study (DESIGN.md §Key design choices): how much each RACE
//! ingredient contributes — Algorithm-4 load balancing, recursion (§4.4),
//! and RCM preordering (§6.1) — measured as η and simulated full-socket
//! GF/s on representative matrices.

use race::cachesim;
use race::gen;
use race::machine;
use race::op::{OpConfig, Operator};
use race::race::RaceConfig;
use race::sim;

fn run(
    name: &str,
    a: &race::sparse::Csr,
    m: &race::machine::Machine,
    cfg: &RaceConfig,
) -> (f64, f64) {
    // ablation variants flip RaceConfig switches through the facade; RCM
    // is applied (or withheld) by the caller, so the handle skips it
    let op = match Operator::build(a, OpConfig::new().rcm(false).race_config(cfg.clone())) {
        Ok(o) => o,
        Err(_) => return (0.0, 0.0),
    };
    let tr = cachesim::measure_symmspmv_traffic(op.upper(), a.nnz(), m);
    let g = sim::simulate_race(m, op.engine(), op.upper(), tr.bytes_total, a.nnz()).gflops;
    let _ = name;
    (op.eta(), g)
}

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let base = machine::skx();
    let t = base.cores;
    println!("SKX socket, {} threads. (eta / simulated GF/s)", t);
    println!(
        "{:<26} {:>16} {:>16} {:>16} {:>16}",
        "matrix", "full RACE", "-loadbalance", "-recursion", "-rcm"
    );
    for name in ["inline_1", "Spin-26", "Graphene-4096", "HPCG-192", "crankseg_1"] {
        let e = gen::corpus_entry(name).unwrap();
        let a0 = (e.build)(small);
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let m = base.scaled_to(a.nrows(), e.paper_nrows);

        let base = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
        let (eta0, g0) = run(name, &a, &m, &base);
        let (eta1, g1) =
            run(name, &a, &m, &RaceConfig { no_load_balance: true, ..base.clone() });
        let (eta2, g2) = run(name, &a, &m, &RaceConfig { no_recursion: true, ..base.clone() });
        // no RCM: build directly on the generator ordering
        let (eta3, g3) = run(name, &a0, &m, &base);
        println!(
            "{:<26} {:>7.3}/{:>7.2} {:>7.3}/{:>7.2} {:>7.3}/{:>7.2} {:>7.3}/{:>7.2}",
            name, eta0, g0, eta1, g1, eta2, g2, eta3, g3
        );
    }
    println!("\n(expected: each ablation costs efficiency or GF/s on at least the");
    println!(" limited-parallelism matrices; RACE's own BFS ordering partially");
    println!(" compensates for missing RCM)");
}
