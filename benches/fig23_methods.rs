//! Fig. 23: SymmSpMV performance comparison RACE vs. MC vs. ABMC over the
//! full corpus on both sockets, matrices ordered by increasing N_r.
//! Paper headline: average RACE speedup 1.5x (ivb) and 1.65x (skx) over
//! the best coloring; ABMC competitive only while the vectors fit in
//! cache.

use race::cachesim;
use race::color::{abmc_schedule, mc_schedule};
use race::gen;
use race::machine;
use race::op::{self, OpConfig, Operator};
use race::race::RaceConfig;
use race::sim;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    for base in [machine::ivb(), machine::skx()] {
        println!("\n== {} (full socket, {} cores; caches scaled per matrix) ==", base.name, base.cores);
        println!(
            "{:>3} {:<26} {:>9} {:>9} {:>9} {:>10}",
            "idx", "matrix", "RACE", "ABMC", "MC", "RACE/best"
        );
        let mut ratios = Vec::new();
        for e in gen::corpus() {
            let a0 = (e.build)(small);
            let perm = race::graph::rcm(&a0);
            let a = a0.permute_symmetric(&perm);
            let m = base.scaled_to(a.nrows(), e.paper_nrows);
            let nnz = a.nnz();
            let t = m.cores;

            let cfg = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            let g_race = match Operator::build(&a, OpConfig::new().rcm(false).race_config(cfg)) {
                Ok(rop) => {
                    let tr = cachesim::measure_symmspmv_traffic(rop.upper(), nnz, &m);
                    sim::simulate_race(&m, rop.engine(), rop.upper(), tr.bytes_total, nnz).gflops
                }
                Err(_) => 0.0,
            };
            let mc = mc_schedule(&a, 2);
            let a_mc = a.permute_symmetric(&mc.perm);
            let up_mc = op::upper(&a_mc);
            let tr_mc = cachesim::measure_symmspmv_traffic(&up_mc, nnz, &m);
            let g_mc = sim::simulate_color(&m, &mc, &up_mc, t, tr_mc.bytes_total, nnz).gflops;

            let abmc = abmc_schedule(&a, (a.nrows() / 64).max(t * 4), 2);
            let a_ab = a.permute_symmetric(&abmc.perm);
            let up_ab = op::upper(&a_ab);
            let tr_ab = cachesim::measure_symmspmv_traffic(&up_ab, nnz, &m);
            let g_ab = sim::simulate_color(&m, &abmc, &up_ab, t, tr_ab.bytes_total, nnz).gflops;

            let best = g_mc.max(g_ab).max(1e-9);
            println!(
                "{:>3} {:<26} {:>9.2} {:>9.2} {:>9.2} {:>9.2}x",
                e.index,
                e.name,
                g_race,
                g_ab,
                g_mc,
                g_race / best
            );
            ratios.push(g_race / best);
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        println!(
            "\naverage RACE speedup over best coloring: {avg:.2}x (paper: 1.5x ivb, 1.65x skx)"
        );
    }
}
