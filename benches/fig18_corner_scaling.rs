//! Fig. 18: SymmSpMV-with-RACE scaling on one Skylake SP socket for the
//! four corner-case matrices, against the SpMV baseline and the roofline
//! windows (RLM-copy / RLM-load), plus the measured memory traffic per
//! nonzero of the symmetric (upper) storage.

use race::cachesim;
use race::gen;
use race::machine;
use race::op::{OpConfig, Operator};
use race::perfmodel;
use race::race::RaceConfig;
use race::sim;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    for name in ["crankseg_1", "inline_1", "parabolic_fem", "Graphene-4096"] {
        let e = gen::corpus_entry(name).unwrap();
        let a0 = (e.build)(small);
        let base = machine::skx();
        let perm = race::graph::rcm(&a0);
        let a = a0.permute_symmetric(&perm);
        let m = base.scaled_to(a.nrows(), e.paper_nrows);
        let nnz = a.nnz();
        println!("\n== {} ({} rows, {} nnz) on {} (scaled caches) ==", name, a.nrows(), nnz, m.name);

        let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);
        let w = perfmodel::symmspmv_window(&m, tr_spmv.alpha, a.nnzr());
        println!(
            "roofline: RLM-copy {:.2} GF/s, RLM-load {:.2} GF/s",
            w.p_copy / 1e9,
            w.p_load / 1e9
        );
        println!("{:>6} {:>10} {:>10} {:>12}", "cores", "RACE GF/s", "SpMV GF/s", "symm B/nnz");
        for t in [1usize, 2, 4, 8, 12, 16, 20] {
            let cfg = RaceConfig { threads: t, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            let ocfg = OpConfig::new().rcm(false).race_config(cfg);
            let (g_race, bpn) = match Operator::build(&a, ocfg) {
                Ok(op) => {
                    let tr = cachesim::measure_symmspmv_traffic(op.upper(), nnz, &m);
                    (
                        sim::simulate_race(&m, op.engine(), op.upper(), tr.bytes_total, nnz).gflops,
                        tr.bytes_per_nnz_stored,
                    )
                }
                Err(_) => (0.0, 0.0),
            };
            let g_spmv = sim::simulate_spmv(&m, &a, t, tr_spmv.bytes_total).gflops;
            println!("{t:>6} {g_race:>10.2} {g_spmv:>10.2} {bpn:>12.2}");
        }
    }
    println!("\n(paper: inline_1/Graphene saturate at roofline; crankseg limited by eta;");
    println!(" parabolic_fem exceeds the model on SKX due to LLC residency)");
}
