//! Fig. 15: parallel efficiency η vs. the RACE input parameters ε₀/ε₁ on
//! the inline_1 analogue, for several thread counts. Reproduces the
//! paper's observation: up to intermediate parallelism the choice hardly
//! matters; at high thread counts large ε values can hurt.

use race::gen;
use race::race::{RaceConfig, RaceEngine};

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    let e = gen::corpus_entry("inline_1").unwrap();
    let a = (e.build)(small);
    println!("inline_1 analogue: {} rows, {} nnz", a.nrows(), a.nnz());

    // Fig. 15(a): eta vs threads for a few eps settings
    println!("\n(a) eta vs N_t:");
    print!("{:>6}", "N_t");
    let eps_settings = [(0.5, 0.5), (0.6, 0.5), (0.8, 0.8), (0.9, 0.9)];
    for (e0, e1) in eps_settings {
        print!("  e0={e0},e1={e1}");
    }
    println!();
    for t in [2usize, 5, 10, 20, 35, 50, 75, 100] {
        print!("{t:>6}");
        for (e0, e1) in eps_settings {
            let cfg = RaceConfig { threads: t, eps: vec![e0, e1, 0.5], ..Default::default() };
            let eta = RaceEngine::build(&a, &cfg).map(|e| e.efficiency()).unwrap_or(0.0);
            print!("  {eta:>11.3}");
        }
        println!();
    }

    // Fig. 15(b-d): eps0 sweep at iso-eps1, three thread counts
    for t in [10usize, 50, 100] {
        println!("\n(b-d) N_t = {t}: eta over eps0 (rows) x eps1 (cols)");
        print!("{:>6}", "e0\\e1");
        for e1 in [0.5, 0.6, 0.7, 0.8, 0.9] {
            print!(" {e1:>7}");
        }
        println!();
        for e0 in [0.5, 0.6, 0.7, 0.8, 0.9] {
            print!("{e0:>6}");
            for e1 in [0.5, 0.6, 0.7, 0.8, 0.9] {
                let cfg = RaceConfig { threads: t, eps: vec![e0, e1, 0.5], ..Default::default() };
                let eta = RaceEngine::build(&a, &cfg).map(|e| e.efficiency()).unwrap_or(0.0);
                print!(" {eta:>7.3}");
            }
            println!();
        }
    }
    println!("\npaper default chosen from this study: eps0 = eps1 = 0.8, eps_(s>1) = 0.5");
}
