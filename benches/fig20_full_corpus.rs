//! Fig. 20: full-corpus SymmSpMV-with-RACE performance vs. the roofline
//! model window and the MKL baselines on both sockets. The MKL-IE
//! SymmSpMV equivalent is plain SpMV on the full matrix (the paper shows
//! they are identical, §6.2.2); "MKL" is the color-phase SymmSpMV.
//! Prints the average speedup vs. SpMV and the average fraction of the
//! roofline achieved — the paper's headline numbers (1.4x/1.5x, ~80-91%).

use race::cachesim;
use race::gen;
use race::machine;
use race::op::{OpConfig, Operator};
use race::perfmodel;
use race::race::RaceConfig;
use race::sim;

fn main() {
    let small = std::env::var("RACE_BENCH_FULL").is_err();
    for base in [machine::ivb(), machine::skx()] {
        println!("\n== {} (full socket, {} cores; caches scaled per matrix) ==", base.name, base.cores);
        println!(
            "{:>3} {:<26} {:>9} {:>9} {:>9} {:>9} {:>9} {:>6}",
            "idx", "matrix", "RACE", "SpMV", "RLMcopy", "RLMload", "eta", "%copy"
        );
        let mut speedups = Vec::new();
        let mut copy_fracs = Vec::new();
        let mut load_fracs = Vec::new();
        for e in gen::corpus() {
            let a0 = (e.build)(small);
            let perm = race::graph::rcm(&a0);
            let a = a0.permute_symmetric(&perm);
            let m = base.scaled_to(a.nrows(), e.paper_nrows);
            let nnz = a.nnz();
            let cfg =
                RaceConfig { threads: m.cores, eps: vec![0.8, 0.8, 0.5], ..Default::default() };
            let op = match Operator::build(&a, OpConfig::new().rcm(false).race_config(cfg)) {
                Ok(o) => o,
                Err(_) => continue,
            };
            let tr = cachesim::measure_symmspmv_traffic(op.upper(), nnz, &m);
            let g_race =
                sim::simulate_race(&m, op.engine(), op.upper(), tr.bytes_total, nnz).gflops;
            let tr_spmv = cachesim::measure_spmv_traffic(&a, &m);
            let g_spmv = sim::simulate_spmv(&m, &a, m.cores, tr_spmv.bytes_total).gflops;
            let w = perfmodel::symmspmv_window(&m, tr_spmv.alpha, a.nnzr());
            let frac = g_race * 1e9 / w.p_copy;
            println!(
                "{:>3} {:<26} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.3} {:>5.0}%",
                e.index,
                e.name,
                g_race,
                g_spmv,
                w.p_copy / 1e9,
                w.p_load / 1e9,
                op.eta(),
                100.0 * frac
            );
            speedups.push(g_race / g_spmv);
            copy_fracs.push(frac.min(1.2));
            load_fracs.push((g_race * 1e9 / w.p_load).min(1.2));
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "\naverage RACE/SpMV speedup: {:.2}x (paper: 1.5x ivb / 1.4x skx)",
            avg(&speedups)
        );
        println!(
            "average roofline fraction: {:.0}% of copy, {:.0}% of load (paper: 91%/83% ivb, 87%/80% skx)",
            100.0 * avg(&copy_fracs),
            100.0 * avg(&load_fracs)
        );
    }
}
