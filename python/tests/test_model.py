"""L2 model tests: CG step and power iteration through the Pallas kernel."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import dense_symmspmv, random_symmetric_dense
from compile.kernels.symmspmv import pack_symmetric
from compile import model

import jax.numpy as jnp


def _packed(a, block=8):
    p = pack_symmetric(a, block=block)
    return p, (
        jnp.asarray(p.cols_u),
        jnp.asarray(p.idx_l),
        jnp.asarray(p.cols_l),
        jnp.asarray(p.vals_u),
    )


def _pad(v, n):
    out = np.zeros(n, dtype=np.float32)
    out[: len(v)] = v
    return jnp.asarray(out)


def test_cg_converges_on_spd():
    n = 24
    a = random_symmetric_dense(n, 0.3, seed=4)  # diagonally dominant -> SPD
    pack, ops = _packed(a)
    rhs = np.ones(n, dtype=np.float32)
    x = _pad(np.zeros(n), pack.n)
    r = _pad(rhs, pack.n)
    p = _pad(rhs, pack.n)
    rs = jnp.dot(r, r)
    rs0 = float(rs)
    for _ in range(60):
        x, r, p, rs = model.cg_step(*ops, x, r, p, rs, block=8)
        if float(rs) < 1e-10 * rs0:
            break
    sol = np.asarray(x)[:n]
    resid = np.linalg.norm(a @ sol - rhs) / np.linalg.norm(rhs)
    assert resid < 1e-3, f"CG residual {resid}"


def test_power_iteration_finds_dominant_eig():
    n = 16
    a = random_symmetric_dense(n, 0.5, seed=8)
    pack, ops = _packed(a)
    v = _pad(np.ones(n) / np.sqrt(n), pack.n)
    lam = 0.0
    for _ in range(200):
        v, lam = model.power_step(*ops, v, block=8)
    lam = float(lam)
    eigs = np.linalg.eigvalsh(a.astype(np.float64))
    dominant = eigs[np.argmax(np.abs(eigs))]
    assert abs(lam - dominant) < 1e-2 * max(1.0, abs(dominant)), (lam, dominant)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_cg_step_preserves_residual_recurrence(seed):
    # after one step: r' must equal rhs - A x' (in exact arithmetic)
    n = 12
    a = random_symmetric_dense(n, 0.5, seed)
    pack, ops = _packed(a)
    rhs = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    x = _pad(np.zeros(n), pack.n)
    r = _pad(rhs, pack.n)
    p = _pad(rhs, pack.n)
    rs = jnp.dot(r, r)
    x1, r1, p1, rs1 = model.cg_step(*ops, x, r, p, rs, block=8)
    want_r = rhs - np.asarray(dense_symmspmv(a, np.asarray(x1)[:n]))
    got_r = np.asarray(r1)[:n]
    np.testing.assert_allclose(got_r, want_r, rtol=5e-3, atol=5e-3 * (1 + np.abs(want_r).max()))
