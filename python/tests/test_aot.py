"""AOT export tests: artifacts lower, parse as HLO text, and carry the
shape contract the Rust runtime expects."""

import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import dense_symmspmv, random_symmetric_dense
from compile.kernels.symmspmv import pack_symmetric


def test_to_hlo_text_roundtrips_simple_fn():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_symmspmv_lowering_contains_expected_shapes():
    cu, il, cl, vu, x = aot.specs(64, 3, 2)
    fn = lambda a, b, c, d, e: model.symmspmv(a, b, c, d, e, block=8)
    text = aot.to_hlo_text(jax.jit(fn).lower(cu, il, cl, vu, x))
    assert "HloModule" in text
    assert "f32[64,3]" in text  # vals_u
    assert "s32[64,2]" in text  # idx_l / cols_l


def test_cg_step_lowering():
    cu, il, cl, vu, x = aot.specs(32, 3, 2)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    fn = lambda a, b, c, d, xv, r, p, rs: model.cg_step(a, b, c, d, xv, r, p, rs, block=8)
    text = aot.to_hlo_text(jax.jit(fn).lower(cu, il, cl, vu, x, f32(32), f32(32), f32()))
    assert "HloModule" in text
    # 4-tuple output
    assert text.count("ROOT") >= 1


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "model.hlo.txt"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--n", "64", "--wu", "3",
         "--wl", "2", "--block", "8"],
        check=True,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
    )
    assert out.exists()
    for name in ["symmspmv", "cg_step", "power_step"]:
        p = tmp_path / f"{name}.hlo.txt"
        assert p.exists(), name
        assert "HloModule" in p.read_text()[:200]
    assert (tmp_path / "shapes.txt").read_text().startswith("n=64")


def test_default_artifact_shape_matches_quickstart_matrix():
    # the 64x64 5-point stencil must pack to the aot.py default shapes —
    # the contract examples/xla_parity.rs relies on
    n = 64
    a = np.zeros((n * n, n * n), dtype=np.float32)
    for j in range(n):
        for i in range(n):
            r = j * n + i
            a[r, r] = 1.0
            for di, dj in [(1, 0), (0, 1)]:
                ii, jj = i + di, j + dj
                if ii < n and jj < n:
                    c = jj * n + ii
                    a[r, c] = a[c, r] = -1.0
    pack = pack_symmetric(a, block=64)
    assert pack.n == 4096 and pack.wu == 3 and pack.wl == 2


def test_power_step_matches_dense():
    a = random_symmetric_dense(16, 0.5, seed=3)
    pack = pack_symmetric(a, block=8)
    ops = (
        jnp.asarray(pack.cols_u),
        jnp.asarray(pack.idx_l),
        jnp.asarray(pack.cols_l),
        jnp.asarray(pack.vals_u),
    )
    v = np.zeros(pack.n, dtype=np.float32)
    v[:16] = 1.0 / 4.0
    v2, lam = model.power_step(*ops, jnp.asarray(v), block=8)
    av = np.asarray(dense_symmspmv(a, np.asarray(v)[:16]))
    want_lam = float(np.asarray(v)[:16] @ av)
    assert abs(float(lam) - want_lam) < 1e-3 * max(1.0, abs(want_lam))
    want_v2 = av / np.linalg.norm(av)
    np.testing.assert_allclose(np.asarray(v2)[:16], want_v2, rtol=2e-3, atol=2e-3)
