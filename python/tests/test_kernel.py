"""Kernel-vs-oracle correctness: the CORE L1 signal.

The Pallas SymmSpMV (interpret mode) must match the dense oracle and the
pure-jnp ELL reference over hypothesis-generated symmetric matrices,
shapes and block sizes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import dense_symmspmv, ell_symmspmv_ref, random_symmetric_dense
from compile.kernels.symmspmv import pack_symmetric, symmspmv_packed


def _check(a_dense, x, block=8, tol=2e-4):
    pack = pack_symmetric(a_dense, block=block)
    got = symmspmv_packed(pack, x, block=block)
    want = np.asarray(dense_symmspmv(a_dense, x))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


def test_identity():
    n = 16
    a = np.eye(n, dtype=np.float32) * 3.0
    x = np.arange(n, dtype=np.float32)
    _check(a, x)


def test_tridiagonal():
    n = 32
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        a[i, i] = 2.0
        if i + 1 < n:
            a[i, i + 1] = a[i + 1, i] = -1.0
    x = np.sin(np.arange(n, dtype=np.float32))
    _check(a, x)


def test_dense_symmetric():
    a = random_symmetric_dense(24, 1.0, seed=7)
    x = np.random.default_rng(3).standard_normal(24).astype(np.float32)
    _check(a, x)


def test_packing_against_jnp_reference():
    a = random_symmetric_dense(20, 0.3, seed=11)
    pack = pack_symmetric(a)
    x = np.random.default_rng(5).standard_normal(20).astype(np.float32)
    xp = np.zeros(pack.n, dtype=np.float32)
    xp[:20] = x
    ref = np.asarray(ell_symmspmv_ref(pack, xp))[:20]
    want = np.asarray(dense_symmspmv(a, x))
    np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-4 * np.abs(want).max())


def test_pad_rows_are_inert():
    # n_orig not a multiple of block: padded rows must produce zeros and
    # not perturb real rows.
    a = random_symmetric_dense(13, 0.4, seed=2)
    pack = pack_symmetric(a, block=8)
    assert pack.n == 16
    x = np.ones(13, dtype=np.float32)
    got = symmspmv_packed(pack, x, block=8)
    want = np.asarray(dense_symmspmv(a, x))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * np.abs(want).max())


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=40),
    density=st.floats(min_value=0.05, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    block=st.sampled_from([4, 8, 16]),
)
def test_hypothesis_sweep(n, density, seed, block):
    a = random_symmetric_dense(n, density, seed)
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal(n).astype(np.float32)
    _check(a, x, block=block)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_diag_dominant_spd(seed):
    # SPD-ish matrices (the CG case)
    a = random_symmetric_dense(17, 0.5, seed)
    x = np.linspace(-1, 1, 17).astype(np.float32)
    _check(a, x)


def test_value_array_stored_once():
    # the symmetry payoff: vals_u holds each value once; the mirror is
    # index-only
    a = random_symmetric_dense(12, 0.6, seed=9)
    pack = pack_symmetric(a)
    nnz_upper = np.count_nonzero(np.triu(a))
    assert pack.vals_u.size >= nnz_upper
    # idx_l points into vals_u: every non-pad index < n*wu
    real = pack.idx_l[pack.idx_l < pack.n * pack.wu]
    strict_upper = np.count_nonzero(np.triu(a, 1))
    assert real.size == strict_upper


def test_rejects_bad_block():
    a = random_symmetric_dense(8, 0.5, seed=1)
    pack = pack_symmetric(a, block=8)
    with pytest.raises(AssertionError):
        # n=8 not a multiple of block=3
        from compile.kernels.symmspmv import symmspmv_apply
        import jax.numpy as jnp

        symmspmv_apply(
            jnp.asarray(pack.cols_u),
            jnp.asarray(pack.idx_l),
            jnp.asarray(pack.cols_l),
            jnp.asarray(pack.vals_u),
            jnp.zeros(pack.n, jnp.float32),
            block=3,
        )
