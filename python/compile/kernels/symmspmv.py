"""Layer-1 Pallas kernel: SymmSpMV over a mirrored padded-ELL layout.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper's CPU
kernel (Algorithm 2) scatters `b[col] += A[idx] * x[row]`, and RACE's
distance-2 coloring exists to make those scatters race-free across
threads. A systolic/vector target (TPU) wants neither scatters nor
colors, so the layout solves the problem instead:

* the **upper triangle** (incl. diagonal) is packed row-major into a padded
  ELL block (``vals_u``, ``cols_u``) — the value array is stored ONCE;
* the **mirrored lower part** is described *by indices only*
  (``idx_l`` pointing into the flattened ``vals_u``, plus ``cols_l``), so
  the transpose contribution becomes a *gather*: symmetric storage still
  halves the 8-byte value traffic, paying only a second 2x4-byte index
  stream — the paper's bandwidth insight, re-expressed for a dataflow
  machine;
* rows are processed in blocks of ``C`` (BlockSpec grid), giving the
  HBM→VMEM schedule the CPU code gets from per-thread level groups.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpret path and the
AOT artifact lowers to plain HLO the Rust runtime executes.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


@dataclass
class SymmEllPack:
    """Mirrored padded-ELL operands for one symmetric matrix.

    Attributes:
        n: padded row count (multiple of the row-block C).
        n_orig: original matrix dimension (n_orig <= n).
        vals_u: (n, wu) f32 — upper-triangle values, diagonal first,
            zero-padded.
        cols_u: (n, wu) i32 — column of each upper value (pad: own row).
        idx_l:  (n, wl) i32 — flat index into vals_u.reshape(-1) for each
            mirrored lower entry (pad: n*wu, a zero slot appended by the
            kernel).
        cols_l: (n, wl) i32 — column of each mirrored entry (pad: own row).
    """

    n: int
    n_orig: int
    vals_u: np.ndarray
    cols_u: np.ndarray
    idx_l: np.ndarray
    cols_l: np.ndarray

    @property
    def wu(self):
        return self.vals_u.shape[1]

    @property
    def wl(self):
        return self.cols_l.shape[1]


def pack_symmetric(a_dense, block=8):
    """Pack a dense symmetric matrix into :class:`SymmEllPack`.

    Mirrors the packing the Rust runtime performs from CSR; kept simple
    (dense input) because it only runs at build/test time.
    """
    a = np.asarray(a_dense, dtype=np.float32)
    n_orig = a.shape[0]
    assert a.shape == (n_orig, n_orig)
    n = ((n_orig + block - 1) // block) * block
    rows_u = []  # (cols, vals) upper incl diag
    for i in range(n_orig):
        cols = [i] + [j for j in range(i + 1, n_orig) if a[i, j] != 0.0]
        vals = [a[i, i]] + [a[i, j] for j in range(i + 1, n_orig) if a[i, j] != 0.0]
        rows_u.append((cols, vals))
    wu = max(len(c) for c, _ in rows_u)
    # strict-lower mirror: entry (i, j) with j < i references upper (j, i)
    rows_l = [[] for _ in range(n_orig)]  # list of (flat_idx, col)
    for j in range(n_orig):
        cols_j = rows_u[j][0]
        for slot, cj in enumerate(cols_j):
            if cj != j:  # strict upper entry (j, cj): mirror into row cj
                rows_l[cj].append((j * wu + slot, j))
    wl = max((len(r) for r in rows_l), default=1)
    wl = max(wl, 1)

    vals_u = np.zeros((n, wu), dtype=np.float32)
    cols_u = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, wu))
    idx_l = np.full((n, wl), n * wu, dtype=np.int32)  # pad -> appended zero
    cols_l = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, wl))
    for i, (cols, vals) in enumerate(rows_u):
        vals_u[i, : len(vals)] = vals
        cols_u[i, : len(cols)] = cols
    for i, ents in enumerate(rows_l):
        for k, (fi, cj) in enumerate(ents):
            idx_l[i, k] = fi
            cols_l[i, k] = cj
    # re-point idx_l pads at the flat length *including* the zero slot
    return SymmEllPack(n=n, n_orig=n_orig, vals_u=vals_u, cols_u=cols_u, idx_l=idx_l, cols_l=cols_l)


def _symmspmv_kernel(cols_u_ref, idx_l_ref, cols_l_ref, vals_u_ref, flat_ref, x_ref, o_ref):
    """Pallas kernel body for one row block.

    Refs:
        cols_u_ref: (C, wu) i32 block of upper columns.
        idx_l_ref:  (C, wl) i32 block of mirrored flat indices.
        cols_l_ref: (C, wl) i32 block of mirrored columns.
        vals_u_ref: (C, wu) f32 block of upper values.
        flat_ref:   (n*wu + 1,) f32 — full flattened vals_u + zero slot.
        x_ref:      (n,) f32 — full input vector (VMEM-resident).
        o_ref:      (C,) f32 — output block.
    """
    x = x_ref[...]
    flat = flat_ref[...]
    vals_u = vals_u_ref[...]
    cols_u = cols_u_ref[...]
    upper = jnp.sum(vals_u * x[cols_u], axis=1)
    vals_l = flat[idx_l_ref[...]]
    lower = jnp.sum(vals_l * x[cols_l_ref[...]], axis=1)
    o_ref[...] = upper + lower


@partial(jax.jit, static_argnames=("block",))
def symmspmv_apply(cols_u, idx_l, cols_l, vals_u, x, block=8):
    """b = A x from mirrored-ELL operands via the Pallas kernel.

    Shapes: cols_u/vals_u (n, wu); idx_l/cols_l (n, wl); x (n,).
    n must be a multiple of `block`.
    """
    n, wu = vals_u.shape
    wl = cols_l.shape[1]
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    flat = jnp.concatenate([vals_u.reshape(-1), jnp.zeros((1,), vals_u.dtype)])
    grid = (n // block,)
    return pl.pallas_call(
        _symmspmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, wu), lambda i: (i, 0)),
            pl.BlockSpec((block, wl), lambda i: (i, 0)),
            pl.BlockSpec((block, wl), lambda i: (i, 0)),
            pl.BlockSpec((block, wu), lambda i: (i, 0)),
            pl.BlockSpec((n * wu + 1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), vals_u.dtype),
        interpret=True,
    )(cols_u, idx_l, cols_l, vals_u, flat, x)


def symmspmv_packed(pack: SymmEllPack, x, block=8):
    """Convenience wrapper: run the kernel from a :class:`SymmEllPack`."""
    xp = np.zeros((pack.n,), dtype=np.float32)
    xp[: pack.n_orig] = np.asarray(x, dtype=np.float32)
    out = symmspmv_apply(
        jnp.asarray(pack.cols_u),
        jnp.asarray(pack.idx_l),
        jnp.asarray(pack.cols_l),
        jnp.asarray(pack.vals_u),
        jnp.asarray(xp),
        block=block,
    )
    return np.asarray(out)[: pack.n_orig]
