"""Pure-jnp correctness oracles for the SymmSpMV kernels.

These are the ground truth every Pallas kernel is tested against at build
time (pytest, hypothesis sweeps). Two references:

* ``dense_symmspmv`` — b = A x on the dense symmetric matrix.
* ``ell_symmspmv_ref`` — the same computation evaluated directly on the
  packed mirrored-ELL operands (validates the packing *and* the kernel
  separately).
"""

import jax.numpy as jnp
import numpy as np


def dense_symmspmv(a_dense, x):
    """b = A x for a dense symmetric matrix (the ultimate oracle)."""
    return jnp.asarray(a_dense) @ jnp.asarray(x)


def ell_symmspmv_ref(pack, x):
    """Evaluate SymmSpMV from a :class:`SymmEllPack` with plain jnp ops.

    b[i] = sum_j vals_u[i,j] * x[cols_u[i,j]]           (upper incl. diag)
         + sum_j vals_flat[idx_l[i,j]] * x[cols_l[i,j]]  (mirrored lower)

    Padding entries have value 0 (upper) / point at a zero slot (lower), so
    they contribute nothing.
    """
    x = jnp.asarray(x)
    vals_u = jnp.asarray(pack.vals_u)
    cols_u = jnp.asarray(pack.cols_u)
    upper = jnp.sum(vals_u * x[cols_u], axis=1)
    flat = jnp.concatenate([vals_u.reshape(-1), jnp.zeros((1,), vals_u.dtype)])
    vals_l = flat[jnp.asarray(pack.idx_l)]
    lower = jnp.sum(vals_l * x[jnp.asarray(pack.cols_l)], axis=1)
    return upper + lower


def random_symmetric_dense(n, density, seed):
    """Random symmetric matrix with ~density off-diagonal fill (numpy)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    vals = rng.standard_normal((n, n)) * mask
    a = np.triu(vals, 1)
    a = a + a.T
    a += np.diag(rng.standard_normal(n) + 2.0 * n * density + 1.0)
    return a.astype(np.float32)
