"""Layer-2 JAX model: the enclosing iterative-solver compute graph.

The paper motivates SymmSpMV as the hot kernel *inside* iterative solvers
(§1). This module expresses that enclosing computation in JAX, calling the
Layer-1 Pallas kernel for every matvec, so the whole step lowers into ONE
HLO module the Rust coordinator executes:

* ``symmspmv`` — a single b = A x (artifact ``symmspmv``).
* ``cg_step`` — one conjugate-gradient iteration (artifact ``cg_step``):
  state (x, r, p, rs_old) → (x', r', p', rs_new).
* ``power_step`` — one normalized power iteration (artifact
  ``power_step``), the eigensolver shape quantum-physics users of these
  matrices run (ScaMaC context).

Everything is shape-specialized at AOT time; python never runs at serve
time.
"""

import jax
import jax.numpy as jnp

from .kernels.symmspmv import symmspmv_apply


def symmspmv(cols_u, idx_l, cols_l, vals_u, x, *, block=8):
    """b = A x via the Pallas kernel (thin L2 alias, jit-compatible)."""
    return symmspmv_apply(cols_u, idx_l, cols_l, vals_u, x, block=block)


def cg_step(cols_u, idx_l, cols_l, vals_u, x, r, p, rs_old, *, block=8):
    """One CG iteration with A applied through the Pallas SymmSpMV.

    Returns (x', r', p', rs_new). The caller loops and tests convergence;
    each call is one artifact execution on the Rust side.
    """
    ap = symmspmv(cols_u, idx_l, cols_l, vals_u, p, block=block)
    p_ap = jnp.dot(p, ap)
    alpha = rs_old / jnp.where(p_ap == 0.0, 1.0, p_ap)
    x_new = x + alpha * p
    r_new = r - alpha * ap
    rs_new = jnp.dot(r_new, r_new)
    beta = rs_new / jnp.where(rs_old == 0.0, 1.0, rs_old)
    p_new = r_new + beta * p
    return x_new, r_new, p_new, rs_new


def power_step(cols_u, idx_l, cols_l, vals_u, v, *, block=8):
    """One power-iteration step: v' = A v / ||A v||, plus the Rayleigh
    quotient estimate. Returns (v', lam)."""
    av = symmspmv(cols_u, idx_l, cols_l, vals_u, v, block=block)
    lam = jnp.dot(v, av)
    nrm = jnp.linalg.norm(av)
    v_new = av / jnp.where(nrm == 0.0, 1.0, nrm)
    return v_new, lam
