"""AOT export: lower the L2/L1 computations to HLO text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format — jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts written (shape-specialized, f32):

* ``symmspmv``    — b = A x.
* ``cg_step``     — one CG iteration.
* ``power_step``  — one power iteration.
* ``model``       — alias of ``symmspmv`` (the default artifact name the
  Makefile tracks).

Default shapes target the quickstart matrix: the 64x64 5-point stencil
(n = 4096, wu = 3, wl = 2, block = 64) — exactly what
``examples/xla_parity.rs`` packs on the Rust side. Override with
--n/--wu/--wl/--block for other matrices.

Usage: python -m compile.aot --out ../artifacts/model.hlo.txt
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs(n, wu, wl):
    """ShapeDtypeStructs for the packed operands (argument order matches
    XlaRuntime::execute_mixed: index arrays first, then f32 data)."""
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return (
        i32(n, wu),  # cols_u
        i32(n, wl),  # idx_l
        i32(n, wl),  # cols_l
        f32(n, wu),  # vals_u
        f32(n),      # x
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--wu", type=int, default=3)
    ap.add_argument("--wl", type=int, default=2)
    ap.add_argument("--block", type=int, default=64)
    args = ap.parse_args()

    out_path = pathlib.Path(args.out)
    art_dir = out_path.parent
    art_dir.mkdir(parents=True, exist_ok=True)
    n, wu, wl, block = args.n, args.wu, args.wl, args.block
    cu, il, cl, vu, x = specs(n, wu, wl)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)

    def emit(name, fn, *spec):
        lowered = jax.jit(fn).lower(*spec)
        text = to_hlo_text(lowered)
        path = art_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
        return text

    symm = lambda cols_u, idx_l, cols_l, vals_u, xv: model.symmspmv(
        cols_u, idx_l, cols_l, vals_u, xv, block=block
    )
    text = emit("symmspmv", symm, cu, il, cl, vu, x)
    # default artifact name tracked by the Makefile
    out_path.write_text(text)
    print(f"wrote {out_path} (alias of symmspmv)")

    emit(
        "cg_step",
        lambda cols_u, idx_l, cols_l, vals_u, xv, r, p, rs: model.cg_step(
            cols_u, idx_l, cols_l, vals_u, xv, r, p, rs, block=block
        ),
        cu, il, cl, vu, x, f32(n), f32(n), f32(),
    )
    emit(
        "power_step",
        lambda cols_u, idx_l, cols_l, vals_u, v: model.power_step(
            cols_u, idx_l, cols_l, vals_u, v, block=block
        ),
        cu, il, cl, vu, x,
    )
    # record the shapes the artifacts were specialized for
    (art_dir / "shapes.txt").write_text(f"n={n} wu={wu} wl={wl} block={block}\n")


if __name__ == "__main__":
    main()
